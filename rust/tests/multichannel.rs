//! Identity contracts of the multi-channel "memory wall" model, across
//! random validated configurations (the `util::prop` substrate):
//!
//! * **beat conservation**: routing a stream over N channels moves exactly
//!   the beats the single-port engine moves, under every `Striping`;
//! * **pre-split parallel replay ≡ entry-wise submit**: one routing pass
//!   plus per-channel streamed replay reproduces the full per-channel
//!   `ReplayState`, for every policy and thread count;
//! * **channels=1 ≡ MemSim bit-for-bit**: a single-port interface is the
//!   plain engine whatever the routing policy or contention knob — at the
//!   simulator level and through the `Session` front door;
//! * **journal determinism**: `channels` × `striping` sweep axes journal
//!   byte-identically serial vs parallel, and resume re-evaluates nothing.

use std::path::PathBuf;

use cfa::dse::{Exhaustive, Explorer, Space};
use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind};
use cfa::layout::cfa::Cfa;
use cfa::memsim::{Dir, MemConfig, MemSim, MultiPortSim, Striping, Txn, TxnTrace};
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::util::prop::{run as prop_run, Config, Gen};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

/// A random always-valid config (cf. `tests/trace_replay.rs`); when
/// `equal_beats` is set, `elem_bytes == bus_bytes` so one element is one
/// beat and splitting a run can never change the beat count.
fn random_cfg(g: &Gen, equal_beats: bool) -> MemConfig {
    let bus_bytes = *g.choose(&[1u64, 2, 4, 8]);
    let elem_bytes = if equal_beats {
        bus_bytes
    } else {
        *g.choose(&[1u64, 2, 4, 8])
    };
    MemConfig {
        elem_bytes,
        bus_bytes,
        clock_mhz: 200.0,
        max_burst_beats: g.i64(16, 256) as u64,
        boundary_bytes: bus_bytes * *g.choose(&[64u64, 512, 4096]),
        issue_cycles: g.i64(0, 8) as u64,
        row_hit_cycles: g.i64(0, 30) as u64,
        row_miss_cycles: g.i64(0, 60) as u64,
        row_bytes: *g.choose(&[256u64, 1024, 8192]),
        banks: g.i64(1, 8) as u64,
        max_outstanding: g.usize(1, 4),
        turnaround_cycles: g.i64(0, 10) as u64,
        cmd_shared_cycles: g.i64(0, 6) as u64,
    }
}

fn random_txns(g: &Gen, n: usize) -> Vec<Txn> {
    (0..n)
        .map(|_| Txn {
            dir: if g.bool() { Dir::Read } else { Dir::Write },
            addr: g.i64(0, 1 << 18) as u64,
            len: g.i64(1, 2000) as u64,
        })
        .collect()
}

/// A 3-facet CFA allocation for resolving `Facet`/`Tile` stripings.
fn test_cfa() -> Cfa {
    let tiling = Tiling::new(vec![24, 24, 24], vec![8, 8, 8]);
    let deps = DepPattern::new(vec![vec![-1, 0, 0], vec![0, -1, 0], vec![0, 0, -2]]).unwrap();
    Cfa::new(tiling, deps).unwrap()
}

/// All three policies, the address stripe drawn at a random (always
/// element-aligned) granularity.
fn random_stripings(g: &Gen, elem_bytes: u64) -> Vec<Striping> {
    vec![
        Striping::Address {
            stripe_bytes: elem_bytes * (1 << g.usize(0, 9)) as u64,
        },
        Striping::Facet,
        Striping::Tile,
    ]
}

#[test]
fn prop_beat_conservation_under_every_striping() {
    prop_run("multichannel beat conservation", Config::small(30), |g| {
        let cfg = random_cfg(g, true);
        let txns = random_txns(g, g.usize(1, 16));
        let ports = g.usize(2, 4);
        let alloc = test_cfa();
        let mut serial = MemSim::new(cfg.clone());
        serial.run(&txns);
        let serial_beats = serial.timing().data_cycles;
        for s in random_stripings(g, cfg.elem_bytes) {
            let map = s.resolve(&alloc, cfg.elem_bytes, ports).unwrap();
            let mut mp = MultiPortSim::new(cfg.clone(), ports, map);
            for t in &txns {
                mp.submit(t);
            }
            // the data buses together move exactly the single-port beats:
            // routing redistributes work, it never creates or loses any
            let beats: u64 = mp.timings().iter().map(|t| t.data_cycles).sum();
            assert_eq!(beats, serial_beats, "{s:?} over {ports} ports");
            // and each channel obeys the engine's accounting identity
            for (p, t) in mp.timings().iter().enumerate() {
                assert_eq!(t.row_hits + t.row_misses, t.axi_bursts, "{s:?} port {p}");
            }
        }
    });
}

#[test]
fn prop_presplit_parallel_replay_equals_entrywise_submit() {
    prop_run("pre-split replay == entry-wise submit", Config::small(30), |g| {
        let cfg = random_cfg(g, false);
        let txns = random_txns(g, g.usize(1, 16));
        let mut trace = TxnTrace::new();
        for t in &txns {
            trace.push(t.dir, t.addr, t.len);
        }
        let ports = g.usize(2, 4);
        let threads = *g.choose(&[1usize, 2, 4]);
        let alloc = test_cfa();
        for s in random_stripings(g, cfg.elem_bytes) {
            let map = s.resolve(&alloc, cfg.elem_bytes, ports).unwrap();
            let mut by_txn = MultiPortSim::new(cfg.clone(), ports, map.clone());
            for t in &txns {
                by_txn.submit(t);
            }
            let mut pre_split = MultiPortSim::new(cfg.clone(), ports, map);
            pre_split.run_trace_parallel(&trace, threads);
            // full per-channel replay state, not just the clocks: the
            // split must be *the* split submit performs, not an equivalent
            assert_eq!(
                pre_split.channel_snapshots(),
                by_txn.channel_snapshots(),
                "{s:?} over {ports} ports, {threads} threads"
            );
            assert_eq!(pre_split.now(), by_txn.now(), "{s:?}");
            assert_eq!(pre_split.aggregate_timing(), by_txn.aggregate_timing(), "{s:?}");
            assert_eq!(
                pre_split.bandwidth(0).raw_bytes,
                by_txn.bandwidth(0).raw_bytes,
                "{s:?}"
            );
        }
    });
}

#[test]
fn prop_single_channel_is_memsim_bit_for_bit_under_every_policy() {
    prop_run("channels=1 == MemSim", Config::small(30), |g| {
        // cmd_shared_cycles is drawn nonzero too: with one channel there
        // is nothing to arbitrate with, so the knob must stay inert
        let cfg = random_cfg(g, false);
        let txns = random_txns(g, g.usize(1, 16));
        let mut trace = TxnTrace::new();
        for t in &txns {
            trace.push(t.dir, t.addr, t.len);
        }
        let mut serial = MemSim::new(cfg.clone());
        serial.run(&txns);
        let alloc = test_cfa();
        for s in random_stripings(g, cfg.elem_bytes) {
            let map = s.resolve(&alloc, cfg.elem_bytes, 1).unwrap();
            let mut mp = MultiPortSim::new(cfg.clone(), 1, map.clone());
            for t in &txns {
                mp.submit(t);
            }
            assert_eq!(mp.now(), serial.now(), "{s:?}");
            assert_eq!(mp.timings()[0], serial.timing(), "{s:?}");
            assert_eq!(mp.channel_snapshots()[0], serial.snapshot(), "{s:?}");
            // the streamed path degenerates identically
            let mut streamed = MultiPortSim::new(cfg.clone(), 1, map);
            streamed.run_trace_parallel(&trace, 2);
            assert_eq!(streamed.channel_snapshots()[0], serial.snapshot(), "{s:?}");
        }
    });
}

#[test]
fn session_single_channel_reports_match_plain_sessions_for_every_striping() {
    // through the front door: a channels=1 spec is the session the stack
    // always ran, whatever striping rides along
    let baseline = ExperimentSpec::builder()
        .named("jacobi2d5p", vec![8, 8, 8], 3)
        .schedule(ScheduleKind::Flat)
        .compile()
        .unwrap()
        .run(Mode::Timing)
        .unwrap();
    for striping in [
        Striping::Address { stripe_bytes: 4096 },
        Striping::Facet,
        Striping::Tile,
    ] {
        let report = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![8, 8, 8], 3)
            .schedule(ScheduleKind::Flat)
            .channels(1)
            .striping(striping.clone())
            .compile()
            .unwrap()
            .run(Mode::Timing)
            .unwrap();
        assert_eq!(report.timing, baseline.timing, "{striping:?}");
        assert_eq!(report.makespan_cycles, baseline.makespan_cycles, "{striping:?}");
        assert_eq!(report.raw_bytes, baseline.raw_bytes, "{striping:?}");
        assert_eq!(report.transactions, baseline.transactions, "{striping:?}");
        assert_eq!(
            report.effective_mb_s.to_bits(),
            baseline.effective_mb_s.to_bits(),
            "{striping:?}"
        );
    }
}

#[test]
fn channel_axes_journal_deterministically_and_resume_evaluates_zero() {
    let space = || {
        let mut s = Space::builtin("tiny").unwrap();
        s.channels = vec![1, 4];
        s.stripings = vec![Striping::default(), Striping::Facet];
        s
    };
    let p1 = tmp("cfa_multichannel_serial.jsonl");
    let p4 = tmp("cfa_multichannel_parallel.jsonl");
    let serial = Explorer::new(space(), Box::new(Exhaustive::new()))
        .parallel(1)
        .journal(&p1)
        .explore()
        .unwrap();
    let par = Explorer::new(space(), Box::new(Exhaustive::new()))
        .parallel(4)
        .journal(&p4)
        .explore()
        .unwrap();
    assert_eq!(serial.evaluated, 32, "tiny (8) x channels (2) x striping (2)");
    assert_eq!(par.evaluated, 32);
    assert_eq!(
        std::fs::read_to_string(&p1).unwrap(),
        std::fs::read_to_string(&p4).unwrap(),
        "channel-axis journals differ between serial and parallel"
    );
    // resume with the full journal performs zero evaluations
    let resumed = Explorer::new(space(), Box::new(Exhaustive::new()))
        .resume(&p1)
        .journal(&p1)
        .explore()
        .unwrap();
    assert_eq!(resumed.evaluated, 0);
    assert_eq!(resumed.resumed, 32);
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
}
