//! Figure-level integration: the quick sweeps must reproduce the *shape*
//! of the paper's evaluation — who wins, by roughly what factor, and where
//! the redundancy sits — for every benchmark in Table I.

use cfa::area::{AreaModel, Device};
use cfa::harness::figures::{area_sweep, fig16_aggregate, measure_bandwidth_named};
use cfa::harness::workloads::table1;
use cfa::layout::registry::{self, names};
use cfa::layout::LayoutRegistry;
use cfa::memsim::MemConfig;

fn measure(
    w: &cfa::harness::workloads::Workload,
    tile: &[i64],
    layout: &str,
    mem: &MemConfig,
    reg: &LayoutRegistry,
) -> cfa::harness::figures::BandwidthPoint {
    measure_bandwidth_named(w, tile, layout, mem, 3, 1, reg).unwrap()
}

#[test]
fn fig15_shape_cfa_wins_effective_bandwidth_everywhere() {
    let mem = MemConfig::default();
    let reg = registry::global();
    for w in table1(true) {
        for tile in &w.tile_sizes {
            let mut eff = std::collections::BTreeMap::new();
            for name in reg.names() {
                let p = measure(&w, tile, name, &mem, &reg);
                assert!(p.raw_mb_s <= mem.peak_mb_s() * 1.001, "{} raw over roofline", w.name);
                assert!(p.effective_mb_s <= p.raw_mb_s * 1.001);
                eff.insert(p.alloc.clone(), p);
            }
            let cfa = &eff[names::CFA];
            for (name, p) in &eff {
                // Strict dominance once every tile dimension reaches 32;
                // below that (notably gaussian's 4-deep time tiles, where
                // the paper itself reports CFA under 80% of the bus until
                // 4x64x64) the swept data-tiling baseline may lead by a
                // small margin — CFA must stay within 15%.
                let slack = if tile.iter().all(|&t| t >= 32) { 0.999 } else { 0.85 };
                assert!(
                    cfa.effective_mb_s >= p.effective_mb_s * slack,
                    "{} tile {tile:?}: cfa {:.1} < {name} {:.1}",
                    w.name,
                    cfa.effective_mb_s,
                    p.effective_mb_s
                );
            }
        }
    }
}

#[test]
fn fig15_shape_cfa_near_roofline_at_32cubed() {
    // the paper: "CFA is able to bring the effective bandwidth close to
    // 100% of the bus bandwidth".
    let mem = MemConfig::default();
    let reg = registry::global();
    for w in table1(true) {
        let tile = w.tile_sizes.iter().find(|t| t[1] >= 32).unwrap();
        let p = measure(&w, tile, names::CFA, &mem, &reg);
        assert!(
            p.effective_mb_s >= 0.85 * mem.peak_mb_s(),
            "{}: CFA effective {:.1} MB/s below 85% of roofline",
            w.name,
            p.effective_mb_s
        );
        assert!(
            p.raw_mb_s >= 0.95 * mem.peak_mb_s(),
            "{}: CFA raw {:.1} below 95%",
            w.name,
            p.raw_mb_s
        );
    }
}

#[test]
fn fig15_shape_baseline_signatures() {
    let mem = MemConfig::default();
    let reg = registry::global();
    for w in table1(true) {
        let tile = &w.tile_sizes[0];
        let orig = measure(&w, tile, names::ORIGINAL, &mem, &reg);
        // original: zero redundancy by construction
        assert_eq!(orig.raw_bytes, orig.useful_bytes, "{}", w.name);
        // bbox: long bursts, heavy redundancy (raw >> effective)
        let bbox = measure(&w, tile, names::BBOX, &mem, &reg);
        assert!(
            bbox.raw_mb_s > 1.5 * bbox.effective_mb_s,
            "{}: bbox raw {:.1} vs eff {:.1} — not redundant enough",
            w.name,
            bbox.raw_mb_s,
            bbox.effective_mb_s
        );
        // CFA issues far fewer transactions than the original layout
        let cfa = measure(&w, tile, names::CFA, &mem, &reg);
        assert!(
            cfa.transactions * 5 < orig.transactions,
            "{}: cfa txns {} vs original {}",
            w.name,
            cfa.transactions,
            orig.transactions
        );
    }
}

#[test]
fn fig16_shape_area_in_paper_bands() {
    // slices 2–5%-ish, DSP below ~5%, CFA not significantly different
    // from the baselines.
    let dev = Device::default();
    let pts = area_sweep(&table1(true), 8, 3);
    for p in &pts {
        let sl = p.est.slice_pct(&dev);
        let dp = p.est.dsp_pct(&dev);
        assert!(
            (1.0..=8.0).contains(&sl),
            "{}/{} slice {sl:.2}% out of band",
            p.benchmark,
            p.alloc
        );
        assert!(dp <= 6.0, "{}/{} dsp {dp:.2}%", p.benchmark, p.alloc);
    }
    let agg = fig16_aggregate(&pts, |e, d| e.slice_pct(d));
    for (bench, cmin, cmax, bmin, bmax) in agg {
        // CFA's span overlaps or stays close to the baseline span
        assert!(
            cmin <= bmax * 1.5 && cmax * 1.5 >= bmin,
            "{bench}: CFA [{cmin:.2},{cmax:.2}] vs baselines [{bmin:.2},{bmax:.2}]"
        );
    }
}

#[test]
fn fig17_shape_bram_cfa_matches_original_bbox_pays() {
    let pts = area_sweep(&table1(true), 8, 3);
    for w in table1(true) {
        let get = |alloc: &str, tile: &Vec<i64>| {
            pts.iter()
                .find(|p| p.benchmark == w.name && p.alloc == alloc && &p.tile == tile)
                .map(|p| p.est.bram36)
                .unwrap()
        };
        for tile in &w.tile_sizes {
            let cfa = get("cfa", tile);
            let orig = get("original", tile);
            let bbox = get("bbox", tile);
            // CFA does not change the on-chip allocation: same ballpark as
            // the original layout
            let ratio = cfa as f64 / orig.max(1) as f64;
            assert!(
                (0.5..=2.0).contains(&ratio),
                "{} {tile:?}: cfa {cfa} vs original {orig} BRAM",
                w.name
            );
            // bounding box holds redundant data on chip
            assert!(
                bbox >= orig,
                "{} {tile:?}: bbox {bbox} < original {orig}",
                w.name
            );
        }
    }
}

#[test]
fn bram_is_the_tile_size_limiter() {
    // §VI.B.3.b: "BRAM was, indeed, the factor limiting tile size" — the
    // largest paper tile sizes approach/exceed the device at f64.
    let model = AreaModel::default();
    let w = &table1(false)[0];
    let deps = cfa::poly::deps::DepPattern::new(w.deps.clone()).unwrap();
    let small = cfa::poly::tiling::Tiling::new(vec![48, 48, 48], vec![16, 16, 16]);
    let large = cfa::poly::tiling::Tiling::new(vec![384, 384, 384], vec![128, 128, 128]);
    let dev = Device::default();
    let b_small = model
        .estimate(&cfa::layout::cfa::Cfa::new(small, deps.clone()).unwrap(), 8)
        .bram_pct(&dev);
    let b_large = model
        .estimate(&cfa::layout::cfa::Cfa::new(large, deps).unwrap(), 8)
        .bram_pct(&dev);
    assert!(b_large > 10.0 * b_small.max(0.1), "small {b_small:.1}% large {b_large:.1}%");
    assert!(b_large > 50.0, "128^3 tiles should strain BRAM: {b_large:.1}%");
}
