//! Cross-allocator integration invariants on the real Table-I workloads.
//!
//! These run every allocation against every benchmark pattern (small tile
//! sizes for point-level checks) and verify the properties the paper's
//! construction guarantees — plus the accounting identities the bandwidth
//! figures depend on.

use cfa::coordinator::AllocKind;
use cfa::harness::workloads::table1;
use cfa::layout::{write_set, Allocation};
use cfa::poly::deps::DepPattern;
use cfa::poly::flow::{coverage_violation, flow_in};
use cfa::poly::tiling::Tiling;
use cfa::util::prop::{run as prop_run, Config};
use cfa::util::rng::Rng;

/// Small tiling for point-level checks: tile edge just above the widths.
fn small_tiling(deps: &DepPattern) -> Tiling {
    let tile: Vec<i64> = deps.widths().iter().map(|w| (w + 2).max(3)).collect();
    let space: Vec<i64> = tile.iter().map(|t| t * 3).collect();
    Tiling::new(space, tile)
}

#[test]
fn coverage_theorem_holds_on_all_benchmarks() {
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = small_tiling(&deps);
        for tc in tiling.tiles() {
            assert_eq!(
                coverage_violation(&tiling, &deps, &tc),
                None,
                "{}: tile {tc:?}",
                w.name
            );
        }
    }
}

#[test]
fn every_allocation_covers_every_flow_in_address() {
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = small_tiling(&deps);
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            for tc in tiling.tiles() {
                let plan = alloc.plan(&tc);
                let covered =
                    |a: u64| plan.read_runs.iter().any(|r| a >= r.addr && a < r.end());
                for pc in &plan.read_pieces {
                    for p in pc.iter_box.points() {
                        let a = alloc.addr_of(pc.array, &p);
                        assert!(
                            covered(a),
                            "{}/{}: tile {tc:?} point {p:?} addr {a} uncovered",
                            w.name,
                            kind.name()
                        );
                    }
                }
                // pieces partition the flow-in exactly
                let fin = flow_in(&tiling, &deps, &tc);
                let piece_vol: u64 =
                    plan.read_pieces.iter().map(|p| p.iter_box.volume()).sum();
                assert_eq!(piece_vol, fin.volume(), "{}/{}", w.name, kind.name());
            }
        }
    }
}

#[test]
fn write_accounting_is_consistent_across_allocators() {
    // all four allocations transfer the same logical write set, so their
    // useful-write counts must agree, and raw >= useful everywhere.
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = small_tiling(&deps);
        for tc in tiling.tiles() {
            let wset = write_set(&tiling, &deps, &tc).volume();
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                let plan = alloc.plan(&tc);
                assert_eq!(
                    plan.write_useful,
                    wset,
                    "{}/{}: tile {tc:?}",
                    w.name,
                    kind.name()
                );
                assert!(plan.write_raw() >= plan.write_useful);
                assert!(plan.read_raw() >= plan.read_useful);
            }
        }
    }
}

#[test]
fn cfa_single_assignment_on_all_benchmarks() {
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = small_tiling(&deps);
        let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for tc in tiling.tiles() {
            for r in alloc.plan(&tc).write_runs {
                intervals.push((r.addr, r.addr + r.len));
            }
        }
        intervals.sort();
        for pair in intervals.windows(2) {
            assert!(
                pair[0].1 <= pair[1].0,
                "{}: overlapping writes {pair:?}",
                w.name
            );
        }
    }
}

#[test]
fn read_write_locs_are_mutually_consistent() {
    // whatever a consumer reads must have been written by the producer.
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = small_tiling(&deps);
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            let mut rng = Rng::new(0xBEEF);
            for _ in 0..200 {
                let p: Vec<i64> = tiling
                    .space
                    .iter()
                    .map(|&n| rng.gen_i64(0, n - 1))
                    .collect();
                let locs = alloc.write_locs(&p);
                if locs.is_empty() {
                    continue; // interior point that never leaves the chip
                }
                let rl = alloc.read_loc(&p);
                assert!(
                    locs.contains(&rl),
                    "{}/{}: read {rl:?} not among writes {locs:?} for {p:?}",
                    w.name,
                    kind.name()
                );
                // addresses stay within the footprint
                for (_, a) in &locs {
                    assert!(*a < alloc.footprint());
                }
            }
        }
    }
}

#[test]
fn cfa_interior_burst_structure_on_3d_benchmarks() {
    // the paper's per-tile transaction count: a handful of long bursts,
    // orders of magnitude below the original layout.
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tile: Vec<i64> = vec![16, 16, 16];
        let tiling = Tiling::new(w.space_for(&tile, 3), tile);
        let cfa = AllocKind::Cfa.build(&tiling, &deps).unwrap();
        let orig = AllocKind::Original.build(&tiling, &deps).unwrap();
        let mid = vec![1, 1, 1];
        let pc = cfa.plan(&mid);
        let po = orig.plan(&mid);
        assert!(
            pc.read_runs.len() <= 8,
            "{}: {} CFA read bursts",
            w.name,
            pc.read_runs.len()
        );
        assert!(
            pc.transactions() * 10 <= po.transactions().max(10),
            "{}: cfa {} vs original {}",
            w.name,
            pc.transactions(),
            po.transactions()
        );
    }
}

#[test]
fn prop_random_patterns_full_pipeline_consistency() {
    prop_run(
        "random backwards patterns: plans valid for all allocators",
        Config::small(15),
        |g| {
            let d = g.usize(2, 3);
            let tile: Vec<i64> = (0..d).map(|_| g.i64(3, 5)).collect();
            let space: Vec<i64> = tile.iter().map(|t| t * g.i64(2, 3)).collect();
            let tiling = Tiling::new(space, tile.clone());
            let mut vecs = Vec::new();
            for _ in 0..g.usize(1, 4) {
                let v: Vec<i64> = (0..d).map(|k| g.i64(-(tile[k].min(2)), 0)).collect();
                if v.iter().any(|&x| x != 0) {
                    vecs.push(v);
                }
            }
            if vecs.is_empty() {
                return;
            }
            let deps = DepPattern::new(vecs).unwrap();
            for kind in AllocKind::ALL {
                let Ok(alloc) = kind.build(&tiling, &deps) else {
                    continue;
                };
                for tc in tiling.tiles() {
                    let plan = alloc.plan(&tc);
                    for r in plan.read_runs.iter().chain(&plan.write_runs) {
                        assert!(r.addr + r.len <= alloc.footprint());
                        assert!(r.len > 0);
                    }
                    assert!(plan.read_raw() >= plan.read_useful);
                }
            }
        },
    );
}

#[test]
fn four_dimensional_space_is_correct_but_less_contiguous() {
    // §IV.J: in d >= 4 the number of second-level neighbor pairs (C(d,2))
    // exceeds the number of facets (d), so not every extension can be
    // merged — CFA stays *correct* (coverage + plan completeness hold) but
    // an interior tile needs more than the 3-D count of read bursts.
    let w = cfa::harness::workloads::heat3d();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![12, 15, 15, 15], vec![4, 5, 5, 5]);
    for tc in tiling.tiles() {
        assert_eq!(coverage_violation(&tiling, &deps, &tc), None, "{tc:?}");
    }
    let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
    let mid = vec![1, 1, 1, 1];
    let plan = alloc.plan(&mid);
    // completeness: every flow-in point covered
    for pc in &plan.read_pieces {
        for p in pc.iter_box.points() {
            let a = alloc.addr_of(pc.array, &p);
            assert!(
                plan.read_runs.iter().any(|r| a >= r.addr && a < r.end()),
                "uncovered 4-D read {p:?}"
            );
        }
    }
    // 4 facets written, one burst each (full-tile contiguity generalizes)
    assert_eq!(plan.write_runs.len(), 4, "{:?}", plan.write_runs);
    // reads: more than the 3-D "4 bursts", but still far below the
    // original layout's scatter
    assert!(plan.read_runs.len() > 4);
    let orig = AllocKind::Original.build(&tiling, &deps).unwrap();
    assert!(plan.read_runs.len() * 10 <= orig.plan(&mid).read_runs.len());
}
