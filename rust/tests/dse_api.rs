//! Acceptance tests for the `dse` design-space explorer:
//!
//! * `Exhaustive` over the Fig-15 space reproduces the figure sweep bit
//!   for bit (the sweep itself is now a thin wrapper over this path);
//! * parallel exploration yields the same journal and Pareto front as
//!   serial, byte for byte;
//! * a killed run resumes from its JSONL journal without re-evaluating
//!   journaled points and finishes with an identical front — and resuming
//!   with a full journal performs zero evaluations;
//! * property tests (the `util::prop` substrate): the reported front is
//!   actually non-dominated (and complete), and `Exhaustive` over tiny
//!   random spaces finds exactly the brute-force best point;
//! * explorer-scaling identities (verification tier 12): early-abort
//!   replay preserves the front byte-for-byte on random spaces, an
//!   N-shard run merged with `journal::merge` reproduces the unsharded
//!   journal exactly, and the analytic cost model fits finitely and
//!   deterministically.

use std::path::{Path, PathBuf};

use cfa::dse::{
    dominates, journal, pareto_indices, CostModel, Evaluation, Exhaustive, Explorer, FeatureMap,
    HillClimb, MemVariant, ModelGuided, Outcome, Space, SpaceWorkload, Strategy, TileSet,
};
use cfa::harness::figures::{self, bandwidth_point_of, measure_bandwidth_named, BandwidthPoint};
use cfa::harness::workloads::table1;
use cfa::layout::registry::{self, names};
use cfa::memsim::MemConfig;
use cfa::poly::vec::IVec;
use cfa::util::prop::{run as prop_run, Config};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

fn assert_same_evals(a: &[Evaluation], b: &[Evaluation], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.fingerprint(), y.fingerprint(), "{ctx}");
        assert_eq!(
            x.effective_mb_s().to_bits(),
            y.effective_mb_s().to_bits(),
            "{ctx}: {}",
            x.fingerprint()
        );
        assert_eq!(
            x.report().unwrap().timing,
            y.report().unwrap().timing,
            "{ctx}"
        );
        assert_eq!(x.area().unwrap(), y.area().unwrap(), "{ctx}");
    }
}

#[test]
fn exhaustive_reproduces_fig15_sweep_bit_identically() {
    let wl = table1(true);
    let reg = registry::global();
    let mem = MemConfig::default();
    let outcome = Explorer::new(Space::fig15(&wl[..2], &mem, 2), Box::new(Exhaustive::new()))
        .registry(reg.clone())
        .explore()
        .unwrap();
    assert_eq!(outcome.evaluated, outcome.points_total);
    // independent reference: the serial measurement loop in sweep order
    let mut manual = Vec::new();
    for w in &wl[..2] {
        for tile in &w.tile_sizes {
            for name in reg.names() {
                manual.push(measure_bandwidth_named(w, tile, name, &mem, 2, 1, &reg).unwrap());
            }
        }
    }
    let dse_pts: Vec<BandwidthPoint> = outcome.all.iter().map(bandwidth_point_of).collect();
    assert_eq!(dse_pts.len(), manual.len());
    for (d, m) in dse_pts.iter().zip(&manual) {
        assert_eq!(d, m);
        assert_eq!(d.raw_mb_s.to_bits(), m.raw_mb_s.to_bits(), "{d:?}");
        assert_eq!(d.effective_mb_s.to_bits(), m.effective_mb_s.to_bits(), "{d:?}");
    }
    // and the public figure sweep is exactly this exploration
    let wrapper = figures::fig15_sweep_registry(&reg, &wl[..2], &mem, 2, 2);
    assert_eq!(wrapper, dse_pts);
}

fn explore_with(strategy: Box<dyn Strategy>, threads: usize, journal_path: &Path) -> Outcome {
    Explorer::new(Space::builtin("tiny").unwrap(), strategy)
        .parallel(threads)
        .journal(journal_path)
        .explore()
        .unwrap()
}

#[test]
fn parallel_exploration_matches_serial_journal_and_front() {
    let p1 = tmp("cfa_dse_serial.jsonl");
    let p4 = tmp("cfa_dse_parallel.jsonl");
    // exhaustive: proposal order is static
    let serial = explore_with(Box::new(Exhaustive::new()), 1, &p1);
    let par = explore_with(Box::new(Exhaustive::new()), 4, &p4);
    assert_eq!(
        std::fs::read_to_string(&p1).unwrap(),
        std::fs::read_to_string(&p4).unwrap(),
        "journals differ between serial and parallel"
    );
    assert_same_evals(&serial.all, &par.all, "exhaustive all");
    assert_same_evals(&serial.front, &par.front, "exhaustive front");
    // hill climb: proposals depend on prior *results*, never on worker
    // interleaving, so the journal sequence is still identical
    let h1 = explore_with(Box::new(HillClimb::new(9)), 1, &p1);
    let h4 = explore_with(Box::new(HillClimb::new(9)), 4, &p4);
    assert_eq!(
        std::fs::read_to_string(&p1).unwrap(),
        std::fs::read_to_string(&p4).unwrap(),
        "hill-climb journals differ between serial and parallel"
    );
    assert_same_evals(&h1.all, &h4.all, "hill all");
    assert_same_evals(&h1.front, &h4.front, "hill front");
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
}

#[test]
fn killed_run_resumes_without_reevaluating_and_front_is_identical() {
    let path = tmp("cfa_dse_resume.jsonl");
    let space = || Space::builtin("tiny").unwrap();
    let reference = Explorer::new(space(), Box::new(Exhaustive::new()))
        .explore()
        .unwrap();
    let total = reference.points_total;
    assert!(total > 3, "tiny space too tiny for the scenario");

    // a "killed" run: budget-limited, journaled
    let first = Explorer::new(space(), Box::new(Exhaustive::new()))
        .budget(3)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!(first.evaluated, 3);

    // resume: completes the space without re-evaluating journaled points
    let resumed = Explorer::new(space(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!(resumed.resumed, 3);
    assert_eq!(resumed.evaluated, total - 3);
    assert_same_evals(&resumed.all, &reference.all, "resumed all");
    assert_same_evals(&resumed.front, &reference.front, "resumed front");

    // resume with the full journal: zero evaluations, identical front
    let nothing = Explorer::new(space(), Box::new(Exhaustive::new()))
        .resume(&path)
        .journal(&path)
        .explore()
        .unwrap();
    assert_eq!(nothing.evaluated, 0);
    assert_eq!(nothing.resumed, total);
    assert_same_evals(&nothing.front, &reference.front, "full-journal front");

    // the journal holds each point exactly once (fingerprint dedup)
    let recorded = journal::read(&path).unwrap();
    assert_eq!(recorded.len(), total);
    let mut fps: Vec<String> = recorded.iter().map(Evaluation::fingerprint).collect();
    fps.sort();
    fps.dedup();
    assert_eq!(fps.len(), total);
    std::fs::remove_file(&path).ok();
}

#[test]
fn prop_pareto_front_is_non_dominated_and_complete() {
    prop_run("pareto front non-domination", Config::default(), |g| {
        let n = g.usize(0, 40);
        let items: Vec<(f64, u64)> = (0..n)
            .map(|_| (g.i64(0, 100) as f64 * 0.5, g.i64(0, 50) as u64))
            .collect();
        let front = pareto_indices(&items, |&p| p);
        for &i in &front {
            assert!(
                !items
                    .iter()
                    .enumerate()
                    .any(|(j, &b)| j != i && dominates(b, items[i])),
                "front member {i} is dominated: {items:?}"
            );
        }
        for i in 0..items.len() {
            if !front.contains(&i) {
                assert!(
                    items
                        .iter()
                        .enumerate()
                        .any(|(j, &b)| j != i && dominates(b, items[i])),
                    "non-front member {i} is undominated: {items:?}"
                );
            }
        }
        // the bandwidth optimum always survives on the front
        if !items.is_empty() {
            let best = items.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
            assert!(front.iter().any(|&i| items[i].0 == best));
        }
    });
}

#[test]
fn prop_early_abort_front_matches_no_abort_on_random_spaces() {
    prop_run("early-abort front == no-abort front", Config::small(4), |g| {
        let wl = table1(true);
        let w = g.choose(&wl);
        let reg = registry::global();
        let tiles: Vec<IVec> = (0..g.usize(1, 2))
            .map(|_| g.choose(&w.tile_sizes).clone())
            .collect();
        let mut layouts: Vec<&str> = reg.names().into_iter().filter(|_| g.bool()).collect();
        if layouts.is_empty() {
            layouts.push(names::CFA);
        }
        let space = Space {
            workloads: vec![SpaceWorkload {
                name: w.name.to_string(),
                deps: w.deps.clone(),
                tiles: TileSet::List(tiles),
            }],
            tiles_per_dim: 2,
            layouts: layouts.iter().map(|s| s.to_string()).collect(),
            mems: vec![MemVariant::paper_default()],
            channels: vec![1],
            stripings: vec![cfa::memsim::Striping::default()],
            pe: vec![64],
        };
        let seed = g.i64(0, 1_000_000) as u64;
        let reference = Explorer::new(space.clone(), Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        let pruned = Explorer::new(space, Box::new(ModelGuided::new(seed)))
            .prune(true)
            .explore()
            .unwrap();
        // the surviving front is byte-identical (order-free: the two
        // strategies visit points in different orders)
        let render = |f: &[Evaluation]| {
            let mut v: Vec<String> = f.iter().map(|e| e.to_json().to_string_compact()).collect();
            v.sort();
            v
        };
        assert_eq!(
            render(&reference.front),
            render(&pruned.front),
            "seed {seed}: pruning changed the front"
        );
        // every point was attempted exactly once, as a replay or a prune
        assert_eq!(
            pruned.evaluated + pruned.pruned,
            reference.evaluated,
            "seed {seed}: attempted counts diverge"
        );
        // completed records are bit-identical to the no-abort run's
        let full = render(&reference.all);
        for e in &pruned.all {
            assert!(
                full.contains(&e.to_json().to_string_compact()),
                "seed {seed}: {} completed with different bytes",
                e.fingerprint()
            );
        }
    });
}

#[test]
fn sharded_merge_reproduces_the_unsharded_journal_byte_for_byte() {
    let space = || Space::builtin("tiny").unwrap();
    let reg = registry::global();
    let enumerated = space().enumerate(&reg).unwrap();
    let total = enumerated.len();

    let unsharded_path = tmp("cfa_dse_unsharded.jsonl");
    let unsharded = Explorer::new(space(), Box::new(Exhaustive::new()))
        .journal(&unsharded_path)
        .explore()
        .unwrap();
    assert_eq!(unsharded.evaluated, total);

    let shards = 2usize;
    let mut shard_paths = Vec::new();
    let mut evaluated_total = 0usize;
    for i in 0..shards {
        let p = tmp(&format!("cfa_dse_shard{i}.jsonl"));
        let out = Explorer::new(space(), Box::new(Exhaustive::new()))
            .shard(i, shards)
            .journal(&p)
            .explore()
            .unwrap();
        // each shard attempts exactly the points the hash assigns it
        let owned = enumerated
            .points()
            .iter()
            .filter(|p| cfa::dse::shard_of(&p.fingerprint(), shards) == i)
            .count();
        assert_eq!(out.evaluated, owned, "shard {i}");
        assert_eq!(out.sharded_out, total - owned, "shard {i}");
        evaluated_total += out.evaluated;
        shard_paths.push(p);
    }
    assert_eq!(evaluated_total, total, "shards overlap or miss points");

    let merged_path = tmp("cfa_dse_merged.jsonl");
    let stats = journal::merge(&merged_path, &shard_paths, Some(&enumerated)).unwrap();
    assert_eq!(stats.written, total);
    assert_eq!(stats.duplicates, 0);
    assert_eq!(stats.out_of_space, 0);
    assert_eq!(
        std::fs::read_to_string(&unsharded_path).unwrap(),
        std::fs::read_to_string(&merged_path).unwrap(),
        "merged shard journal differs from the unsharded run's"
    );

    // resuming from the merged journal evaluates nothing new and lands on
    // the identical front
    let resumed = Explorer::new(space(), Box::new(Exhaustive::new()))
        .resume(&merged_path)
        .explore()
        .unwrap();
    assert_eq!(resumed.evaluated, 0);
    assert_eq!(resumed.resumed, total);
    assert_same_evals(&resumed.front, &unsharded.front, "merged-resume front");

    std::fs::remove_file(&unsharded_path).ok();
    std::fs::remove_file(&merged_path).ok();
    for p in &shard_paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn prop_model_fit_is_finite_and_refit_deterministic() {
    // training rows from a real exploration of the tiny space
    let reg = registry::global();
    let space = || Space::builtin("tiny").unwrap();
    let enumerated = space().enumerate(&reg).unwrap();
    let outcome = Explorer::new(space(), Box::new(Exhaustive::new()))
        .explore()
        .unwrap();
    let fm = FeatureMap::for_space(enumerated.points());
    let mem = MemConfig::default();
    let xs: Vec<Vec<f64>> = outcome
        .all
        .iter()
        .map(|e| fm.features(e.point(), &mem))
        .collect();
    let ys: Vec<f64> = outcome.all.iter().map(|e| e.effective_mb_s()).collect();
    let model = CostModel::fit(&xs, &ys, 1e-3);
    assert!(model.rms_error(&xs, &ys).is_finite(), "training error diverged");
    for x in &xs {
        assert!(model.predict(x).is_finite());
    }
    // refitting the same rows is bit-identical
    let again = CostModel::fit(&xs, &ys, 1e-3);
    for (a, b) in model.weights.iter().zip(&again.weights) {
        assert_eq!(a.to_bits(), b.to_bits(), "refit is not deterministic");
    }
    // ... and on random targets the solver never emits NaN/inf, even for
    // degenerate (constant, tiny, colinear) target vectors
    prop_run("model fit finite on random targets", Config::small(6), |g| {
        let n = g.usize(1, xs.len());
        let rows = &xs[..n];
        let targets: Vec<f64> = (0..n).map(|_| g.i64(-1000, 1000) as f64 * 0.125).collect();
        let m = CostModel::fit(rows, &targets, 1e-3);
        assert!(m.rms_error(rows, &targets).is_finite());
        for x in rows {
            assert!(m.predict(x).is_finite());
        }
    });
    // a fixed-seed model-guided run is end-to-end deterministic: two runs
    // journal byte-identical files (refits included)
    let j1 = tmp("cfa_dse_model_det1.jsonl");
    let j2 = tmp("cfa_dse_model_det2.jsonl");
    for p in [&j1, &j2] {
        Explorer::new(space(), Box::new(ModelGuided::new(17)))
            .journal(p)
            .explore()
            .unwrap();
    }
    assert_eq!(
        std::fs::read_to_string(&j1).unwrap(),
        std::fs::read_to_string(&j2).unwrap(),
        "fixed-seed model-guided runs diverged"
    );
    std::fs::remove_file(&j1).ok();
    std::fs::remove_file(&j2).ok();
}

#[test]
fn prop_exhaustive_finds_bruteforce_best_on_tiny_spaces() {
    prop_run("exhaustive == brute-force best", Config::small(4), |g| {
        let wl = table1(true);
        let w = g.choose(&wl);
        let reg = registry::global();
        let tiles: Vec<IVec> = (0..g.usize(1, 2))
            .map(|_| g.choose(&w.tile_sizes).clone())
            .collect();
        let mut layouts: Vec<&str> = reg.names().into_iter().filter(|_| g.bool()).collect();
        if layouts.is_empty() {
            layouts.push(names::CFA);
        }
        let space = Space {
            workloads: vec![SpaceWorkload {
                name: w.name.to_string(),
                deps: w.deps.clone(),
                tiles: TileSet::List(tiles.clone()),
            }],
            tiles_per_dim: 2,
            layouts: layouts.iter().map(|s| s.to_string()).collect(),
            mems: vec![MemVariant::paper_default()],
            channels: vec![1],
            stripings: vec![cfa::memsim::Striping::default()],
            pe: vec![64],
        };
        let outcome = Explorer::new(space, Box::new(Exhaustive::new()))
            .explore()
            .unwrap();
        // brute-force recomputation, independent of the explorer
        let mut uniq: Vec<IVec> = Vec::new();
        for t in &tiles {
            if !uniq.contains(t) {
                uniq.push(t.clone());
            }
        }
        let mem = MemConfig::default();
        let mut best: Option<BandwidthPoint> = None;
        for tile in &uniq {
            for layout in &layouts {
                let p = measure_bandwidth_named(w, tile, layout, &mem, 2, 1, &reg).unwrap();
                if best
                    .as_ref()
                    .map(|b| p.effective_mb_s > b.effective_mb_s)
                    .unwrap_or(true)
                {
                    best = Some(p);
                }
            }
        }
        let best = best.expect("non-empty space");
        assert_eq!(outcome.evaluated, uniq.len() * layouts.len());
        let explored_best = outcome
            .all
            .iter()
            .map(|e| e.effective_mb_s())
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(
            explored_best.to_bits(),
            best.effective_mb_s.to_bits(),
            "explorer best {explored_best} vs brute force {}",
            best.effective_mb_s
        );
        // and that optimum sits on the reported front
        assert!(outcome
            .front
            .iter()
            .any(|e| e.effective_mb_s().to_bits() == best.effective_mb_s.to_bits()));
    });
}
