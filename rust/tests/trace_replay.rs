//! Acceptance tests for the trace compilation + fast replay subsystem:
//!
//! * the coalesced streaming kernel (`MemSim::run_trace`) and the scalar
//!   trace replay are **bit-identical** — full `ReplayState`, counters
//!   included — to the scalar `MemSim::run`, across random `Txn` streams ×
//!   random (validated) `MemConfig`s;
//! * a `Session`'s compiled trace replays bit-identically to
//!   `Session::run(Mode::Timing)` for every registered layout;
//! * a `TraceCache` hit evaluates bit-identically to a cold compile, and a
//!   `cfa tune`-shaped exploration journals **byte-identical** files with
//!   the cache on and off (the PR's acceptance criterion, on the
//!   `fig15-quick` builtin);
//! * degenerate memory configs error at the `dse` space-parsing front door
//!   instead of panicking inside the simulator.

use std::path::PathBuf;
use std::sync::Arc;

use cfa::dse::{geometry_key, Evaluator, Exhaustive, Explorer, Space};
use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind};
use cfa::layout::registry;
use cfa::memsim::{Dir, MemConfig, MemSim, TraceCache, Txn, TxnTrace};
use cfa::util::prop::{run as prop_run, Config, Gen};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

/// A random but always-valid memory configuration: every field the
/// simulator divides by is nonzero and the AXI boundary is a multiple of
/// the bus width. Roughly half the draws satisfy the streaming conditions
/// (exercising the coalesced kernel), the rest exercise its scalar
/// fallback — identity must hold either way.
fn random_cfg(g: &Gen) -> MemConfig {
    let bus_bytes = *g.choose(&[1u64, 2, 4, 8, 16]);
    // keep the minimum chunk >= 64 bytes so the burst count per test case
    // stays bounded even for the smallest bus widths
    let boundary_bytes = bus_bytes * *g.choose(&[64u64, 512, 4096]);
    MemConfig {
        elem_bytes: *g.choose(&[1u64, 2, 4, 8]),
        bus_bytes,
        clock_mhz: 100.0,
        max_burst_beats: g.i64(16, 256) as u64,
        boundary_bytes,
        issue_cycles: g.i64(0, 8) as u64,
        row_hit_cycles: g.i64(0, 30) as u64,
        row_miss_cycles: g.i64(0, 60) as u64,
        row_bytes: *g.choose(&[256u64, 1024, 8192, 600]),
        banks: g.i64(1, 8) as u64,
        max_outstanding: g.usize(1, 4),
        turnaround_cycles: g.i64(0, 10) as u64,
        cmd_shared_cycles: g.i64(0, 6) as u64,
    }
}

fn random_txns(g: &Gen, n: usize) -> Vec<Txn> {
    (0..n)
        .map(|_| Txn {
            dir: if g.bool() { Dir::Read } else { Dir::Write },
            addr: g.i64(0, 1 << 18) as u64,
            len: g.i64(1, 4096) as u64,
        })
        .collect()
}

fn trace_of(txns: &[Txn]) -> TxnTrace {
    let mut t = TxnTrace::new();
    for x in txns {
        t.push(x.dir, x.addr, x.len);
    }
    t
}

#[test]
fn prop_trace_replay_bit_identical_to_scalar_run() {
    prop_run(
        "run_trace == run_trace_scalar == run",
        Config::small(80),
        |g| {
            let cfg = random_cfg(g);
            let txns = random_txns(g, g.usize(1, 16));
            let trace = trace_of(&txns);
            let mut scalar = MemSim::new(cfg.clone());
            let mut streamed = MemSim::new(cfg.clone());
            let mut trace_scalar = MemSim::new(cfg.clone());
            let a = scalar.run(&txns);
            let b = streamed.run_trace(&trace);
            let c = trace_scalar.run_trace_scalar(&trace);
            assert_eq!(a, b, "streamed cycles diverged ({cfg:?})");
            assert_eq!(a, c, "scalar trace cycles diverged ({cfg:?})");
            // the whole replay state — bank rows, in-flight window, clocks,
            // every counter — must match, not just the headline number
            assert_eq!(scalar.snapshot(), streamed.snapshot(), "{cfg:?}");
            assert_eq!(scalar.snapshot(), trace_scalar.snapshot(), "{cfg:?}");
        },
    );
}

#[test]
fn prop_streaming_survives_contiguous_runs_and_turnarounds() {
    // adversarial shape for the coalesced kernel: long contiguous
    // same-direction spans (bulk advance territory) interleaved with
    // direction flips and short scattered bursts
    prop_run("streaming on contiguous spans", Config::small(40), |g| {
        let cfg = MemConfig {
            max_outstanding: g.usize(2, 4),
            ..MemConfig::default()
        };
        let mut txns = Vec::new();
        let mut cursor = g.i64(0, 1000) as u64;
        for _ in 0..g.usize(1, 10) {
            match g.usize(0, 2) {
                0 => {
                    // a long contiguous read span, possibly split into
                    // back-to-back transactions
                    let pieces = g.usize(1, 3);
                    for _ in 0..pieces {
                        let len = g.i64(1, 1 << 16) as u64;
                        txns.push(Txn {
                            dir: Dir::Read,
                            addr: cursor,
                            len,
                        });
                        cursor += len;
                    }
                }
                1 => {
                    let len = g.i64(1, 64) as u64;
                    txns.push(Txn {
                        dir: Dir::Write,
                        addr: g.i64(0, 1 << 20) as u64,
                        len,
                    });
                }
                _ => {
                    cursor = g.i64(0, 1 << 20) as u64;
                }
            }
        }
        if txns.is_empty() {
            return;
        }
        let trace = trace_of(&txns);
        let mut scalar = MemSim::new(cfg.clone());
        let mut streamed = MemSim::new(cfg.clone());
        assert!(streamed.streaming_enabled());
        scalar.run(&txns);
        streamed.run_trace(&trace);
        assert_eq!(scalar.snapshot(), streamed.snapshot());
    });
}

#[test]
fn session_trace_replay_matches_timing_mode_across_layouts() {
    // the dse evaluator's exact shape: flat schedule, Mode::Timing
    for layout in registry::global().names() {
        let session = ExperimentSpec::builder()
            .named("jacobi2d5p", vec![16, 16, 16], 3)
            .layout(layout)
            .schedule(ScheduleKind::Flat)
            .compile()
            .unwrap();
        let direct = session.run(Mode::Timing).unwrap();
        let trace = session.compile_trace();
        assert_eq!(trace.transactions(), direct.transactions, "{layout}");
        let replayed = session.run_trace(&trace).unwrap();
        assert_eq!(replayed.timing, direct.timing, "{layout}");
        assert_eq!(replayed.makespan_cycles, direct.makespan_cycles);
        assert_eq!(replayed.raw_bytes, direct.raw_bytes);
        assert_eq!(replayed.useful_bytes, direct.useful_bytes);
        assert_eq!(
            replayed.effective_mb_s.to_bits(),
            direct.effective_mb_s.to_bits(),
            "{layout}"
        );
    }
}

#[test]
fn cache_hit_evaluates_bit_identically_to_cold_compile() {
    // two mem variants of one geometry: the second evaluation hits the
    // trace the first compiled; both must equal the uncached evaluator's
    // results field for field (wall_secs is normalized, so full JSON
    // equality is the strongest possible check)
    let mut space = Space::builtin("tiny").unwrap();
    space.mems.push(cfa::dse::MemVariant::new(
        "narrow",
        MemConfig {
            max_outstanding: 4,
            max_burst_beats: 64,
            ..MemConfig::default()
        },
    ));
    let reg = registry::global();
    let points = space.enumerate(&reg).unwrap();
    assert!(points.len() >= 16, "expected mem-variant pairs");
    let cache = Arc::new(TraceCache::new());
    let cached_ev = Evaluator::new(&space, reg.clone()).with_trace_cache(cache.clone());
    let cold_ev = Evaluator::new(&space, reg.clone());
    for p in points.points() {
        let warm = cached_ev.evaluate(p).unwrap();
        let cold = cold_ev.evaluate(p).unwrap();
        assert_eq!(
            warm.to_json().to_string_compact(),
            cold.to_json().to_string_compact(),
            "{}",
            p.fingerprint()
        );
    }
    // geometries = points / mem variants; every extra variant was a hit
    assert_eq!(cache.len(), points.len() / space.mems.len());
    assert!(cache.hits() > 0, "no trace reuse observed");
    // evaluating the same point again is a pure cache hit
    let before = cache.hits();
    cached_ev.evaluate(&points.points()[0]).unwrap();
    assert_eq!(cache.hits(), before + 1);
}

#[test]
fn geometry_key_ignores_mem_and_pe_only() {
    let space = Space::builtin("tiny").unwrap();
    let reg = registry::global();
    let points = space.enumerate(&reg).unwrap();
    let p0 = &points.points()[0];
    let deps = &space.workload(&p0.workload).unwrap().deps;
    let space_box: Vec<i64> = p0.tile.iter().map(|t| t * space.tiles_per_dim).collect();
    let k0 = geometry_key(p0, &space_box, deps);
    let mut mem_variant = p0.clone();
    mem_variant.mem = "other".into();
    mem_variant.pe = 999;
    assert_eq!(geometry_key(&mem_variant, &space_box, deps), k0);
    let mut other_layout = p0.clone();
    other_layout.layout = "something-else".into();
    assert_ne!(geometry_key(&other_layout, &space_box, deps), k0);
    let mut other_tile = p0.clone();
    other_tile.tile[0] += 1;
    assert_ne!(geometry_key(&other_tile, &space_box, deps), k0);
    // a same-named workload with a different dependence pattern must not
    // alias (caches may be shared across spaces)
    let mut other_deps = deps.clone();
    other_deps.push(vec![0, -2, 0]);
    assert_ne!(geometry_key(p0, &space_box, &other_deps), k0);
}

#[test]
fn tune_journal_bytes_identical_with_cache_on_and_off() {
    // the PR's acceptance criterion, on the fig15-quick builtin
    let space = || Space::builtin("fig15-quick").unwrap();
    let on = tmp("cfa_trace_tune_on.jsonl");
    let off = tmp("cfa_trace_tune_off.jsonl");
    Explorer::new(space(), Box::new(Exhaustive::new()))
        .parallel(2)
        .trace_cache(true)
        .journal(&on)
        .explore()
        .unwrap();
    Explorer::new(space(), Box::new(Exhaustive::new()))
        .trace_cache(false)
        .journal(&off)
        .explore()
        .unwrap();
    let on_bytes = std::fs::read(&on).unwrap();
    let off_bytes = std::fs::read(&off).unwrap();
    assert!(!on_bytes.is_empty());
    assert_eq!(
        on_bytes, off_bytes,
        "trace cache changed journal bytes (fig15-quick)"
    );
    std::fs::remove_file(&on).ok();
    std::fs::remove_file(&off).ok();
}

#[test]
fn tune_journal_bytes_identical_with_profile_on_and_off() {
    // span capture records wall time, which must never feed the journal:
    // a tune run under --profile writes byte-identical records
    let space = || Space::builtin("tiny").unwrap();
    let plain = tmp("cfa_trace_tune_noprof.jsonl");
    let profiled = tmp("cfa_trace_tune_prof.jsonl");
    Explorer::new(space(), Box::new(Exhaustive::new()))
        .journal(&plain)
        .explore()
        .unwrap();
    let cap = cfa::obs::begin_capture();
    Explorer::new(space(), Box::new(Exhaustive::new()))
        .journal(&profiled)
        .explore()
        .unwrap();
    let events = cap.finish();
    assert!(
        events.iter().any(|e| e.name == "dse::evaluate"),
        "the capture saw the evaluation spans"
    );
    let a = std::fs::read(&plain).unwrap();
    let b = std::fs::read(&profiled).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "span capture changed journal bytes");
    std::fs::remove_file(&plain).ok();
    std::fs::remove_file(&profiled).ok();
}

#[test]
fn degenerate_space_configs_error_at_parse_time() {
    let err = Space::parse(
        r#"{"workloads": ["jacobi2d5p"],
            "mem": [{"name": "zero-window", "max_outstanding": 0}]}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("max_outstanding"), "{err}");
}
