//! Accounting identities of the AXI/DRAM timing engine, checked against
//! independent re-computations of the burst segmentation:
//!
//! * every AXI burst's first beat is exactly one row hit or row miss:
//!   `row_hits + row_misses == axi_bursts`;
//! * `data_cycles` equals the total beats transferred;
//! * `turnarounds` equals the number of read↔write direction changes in
//!   the submitted stream;
//! * `axi_bursts` equals the segmentation count (≤256-beat bursts, no
//!   4 KiB boundary crossing).

use cfa::memsim::{Dir, MemConfig, MemSim, Txn};
use cfa::util::prop::{run as prop_run, Config, Gen};

/// Re-derive the burst segmentation of one transaction exactly as
/// `MemSim::submit` performs it; returns (bursts, beats).
fn segmentation(cfg: &MemConfig, txn: &Txn) -> (u64, u64) {
    let mut addr_b = txn.addr * cfg.elem_bytes;
    let mut remaining_b = txn.len * cfg.elem_bytes;
    let (mut bursts, mut beats) = (0u64, 0u64);
    while remaining_b > 0 {
        let to_boundary = cfg.boundary_bytes - (addr_b % cfg.boundary_bytes);
        let max_bytes = cfg.max_burst_beats * cfg.bus_bytes;
        let chunk = remaining_b.min(to_boundary).min(max_bytes);
        bursts += 1;
        beats += chunk.div_ceil(cfg.bus_bytes);
        addr_b += chunk;
        remaining_b -= chunk;
    }
    (bursts, beats)
}

fn random_txns(g: &Gen, n: usize) -> Vec<Txn> {
    (0..n)
        .map(|_| Txn {
            dir: if g.bool() { Dir::Read } else { Dir::Write },
            addr: g.i64(0, 1 << 20) as u64,
            len: g.i64(1, 5000) as u64,
        })
        .collect()
}

#[test]
fn prop_accounting_identities_hold() {
    prop_run("memsim accounting identities", Config::small(80), |g| {
        let cfg = MemConfig::default();
        let txns = random_txns(g, g.usize(1, 24));
        let mut sim = MemSim::new(cfg.clone());
        sim.run(&txns);
        let t = sim.timing().clone();

        let (mut bursts, mut beats) = (0u64, 0u64);
        for txn in &txns {
            let (b, d) = segmentation(&cfg, txn);
            bursts += b;
            beats += d;
        }
        // every burst's first beat is classified exactly once
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts, "{t:?}");
        // the segmentation is the burst count
        assert_eq!(t.axi_bursts, bursts, "{t:?}");
        // the data bus moved exactly the transferred beats
        assert_eq!(t.data_cycles, beats, "{t:?}");
        // direction changes (bursts of one txn share its direction)
        let switches = txns.windows(2).filter(|w| w[0].dir != w[1].dir).count() as u64;
        assert_eq!(t.turnarounds, switches, "{t:?}");
        // the bus is one beat per cycle: makespan bounds the data phase
        assert!(t.cycles >= t.data_cycles, "{t:?}");
        assert_eq!(t.cycles, sim.now());
    });
}

#[test]
fn prop_identities_hold_with_narrow_elements_and_offsets() {
    // unaligned element sizes exercise the div_ceil path of the beat count
    prop_run("identities with 4-byte elements", Config::small(40), |g| {
        // small rows also exercise the mid-burst row-switch path (rows
        // larger than the 4 KiB AXI boundary can never be crossed
        // mid-burst, so the default config keeps row_switches at zero)
        let cfg = MemConfig {
            elem_bytes: 4,
            row_bytes: 1024,
            ..MemConfig::default()
        };
        let txns = random_txns(g, g.usize(1, 12));
        let mut sim = MemSim::new(cfg.clone());
        sim.run(&txns);
        let t = sim.timing().clone();
        let (mut bursts, mut beats) = (0u64, 0u64);
        for txn in &txns {
            let (b, d) = segmentation(&cfg, txn);
            bursts += b;
            beats += d;
        }
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
        assert_eq!(t.axi_bursts, bursts);
        assert_eq!(t.data_cycles, beats);
    });
}

#[test]
fn identities_survive_reset_and_reuse() {
    let cfg = MemConfig::default();
    let mut sim = MemSim::new(cfg.clone());
    let txns = [
        Txn {
            dir: Dir::Read,
            addr: 0,
            len: 700,
        },
        Txn {
            dir: Dir::Write,
            addr: 100_000,
            len: 3,
        },
        Txn {
            dir: Dir::Read,
            addr: 512,
            len: 1,
        },
    ];
    sim.run(&txns);
    let first = sim.timing().clone();
    assert_eq!(first.row_hits + first.row_misses, first.axi_bursts);
    assert_eq!(first.turnarounds, 2);
    sim.reset();
    assert_eq!(sim.timing(), &cfa::memsim::Timing::default());
    sim.run(&txns);
    // a reset simulator replays the same stream to the same counters
    assert_eq!(sim.timing(), &first);
}

#[test]
fn measure_reports_all_observed_activates() {
    // Bandwidth::row_misses keeps its historical meaning: first-beat
    // misses plus mid-burst row switches
    let cfg = MemConfig {
        row_bytes: 1024, // rows below the AXI boundary -> mid-burst crossings
        ..MemConfig::default()
    };
    let mut sim = MemSim::new(cfg);
    let bw = sim.measure(
        &[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 8192, // 64 KiB: many 1 KiB rows
        }],
        8192,
    );
    let t = sim.timing().clone();
    assert!(t.row_switches > 0, "{t:?}");
    assert_eq!(bw.row_misses, t.row_misses + t.row_switches);
    assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
}
