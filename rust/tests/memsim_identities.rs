//! Accounting identities of the AXI/DRAM timing engine, checked against
//! independent re-computations of the burst segmentation:
//!
//! * every AXI burst's first beat is exactly one row hit or row miss:
//!   `row_hits + row_misses == axi_bursts`;
//! * `data_cycles` equals the total beats transferred;
//! * `turnarounds` equals the number of read↔write direction changes in
//!   the submitted stream;
//! * `axi_bursts` equals the segmentation count (≤256-beat bursts, no
//!   4 KiB boundary crossing).

use cfa::memsim::{cfa_port_map, Dir, MemConfig, MemSim, MultiPortSim, PortMap, Txn};
use cfa::util::prop::{run as prop_run, Config, Gen};

/// Re-derive the burst segmentation of one transaction exactly as
/// `MemSim::submit` performs it; returns (bursts, beats).
fn segmentation(cfg: &MemConfig, txn: &Txn) -> (u64, u64) {
    let mut addr_b = txn.addr * cfg.elem_bytes;
    let mut remaining_b = txn.len * cfg.elem_bytes;
    let (mut bursts, mut beats) = (0u64, 0u64);
    while remaining_b > 0 {
        let to_boundary = cfg.boundary_bytes - (addr_b % cfg.boundary_bytes);
        let max_bytes = cfg.max_burst_beats * cfg.bus_bytes;
        let chunk = remaining_b.min(to_boundary).min(max_bytes);
        bursts += 1;
        beats += chunk.div_ceil(cfg.bus_bytes);
        addr_b += chunk;
        remaining_b -= chunk;
    }
    (bursts, beats)
}

fn random_txns(g: &Gen, n: usize) -> Vec<Txn> {
    (0..n)
        .map(|_| Txn {
            dir: if g.bool() { Dir::Read } else { Dir::Write },
            addr: g.i64(0, 1 << 20) as u64,
            len: g.i64(1, 5000) as u64,
        })
        .collect()
}

#[test]
fn prop_accounting_identities_hold() {
    prop_run("memsim accounting identities", Config::small(80), |g| {
        let cfg = MemConfig::default();
        let txns = random_txns(g, g.usize(1, 24));
        let mut sim = MemSim::new(cfg.clone());
        sim.run(&txns);
        let t = sim.timing().clone();

        let (mut bursts, mut beats) = (0u64, 0u64);
        for txn in &txns {
            let (b, d) = segmentation(&cfg, txn);
            bursts += b;
            beats += d;
        }
        // every burst's first beat is classified exactly once
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts, "{t:?}");
        // the segmentation is the burst count
        assert_eq!(t.axi_bursts, bursts, "{t:?}");
        // the data bus moved exactly the transferred beats
        assert_eq!(t.data_cycles, beats, "{t:?}");
        // direction changes (bursts of one txn share its direction)
        let switches = txns.windows(2).filter(|w| w[0].dir != w[1].dir).count() as u64;
        assert_eq!(t.turnarounds, switches, "{t:?}");
        // the bus is one beat per cycle: makespan bounds the data phase
        assert!(t.cycles >= t.data_cycles, "{t:?}");
        assert_eq!(t.cycles, sim.now());
    });
}

#[test]
fn prop_identities_hold_with_narrow_elements_and_offsets() {
    // unaligned element sizes exercise the div_ceil path of the beat count
    prop_run("identities with 4-byte elements", Config::small(40), |g| {
        // small rows also exercise the mid-burst row-switch path (rows
        // larger than the 4 KiB AXI boundary can never be crossed
        // mid-burst, so the default config keeps row_switches at zero)
        let cfg = MemConfig {
            elem_bytes: 4,
            row_bytes: 1024,
            ..MemConfig::default()
        };
        let txns = random_txns(g, g.usize(1, 12));
        let mut sim = MemSim::new(cfg.clone());
        sim.run(&txns);
        let t = sim.timing().clone();
        let (mut bursts, mut beats) = (0u64, 0u64);
        for txn in &txns {
            let (b, d) = segmentation(&cfg, txn);
            bursts += b;
            beats += d;
        }
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
        assert_eq!(t.axi_bursts, bursts);
        assert_eq!(t.data_cycles, beats);
    });
}

#[test]
fn identities_survive_reset_and_reuse() {
    let cfg = MemConfig::default();
    let mut sim = MemSim::new(cfg.clone());
    let txns = [
        Txn {
            dir: Dir::Read,
            addr: 0,
            len: 700,
        },
        Txn {
            dir: Dir::Write,
            addr: 100_000,
            len: 3,
        },
        Txn {
            dir: Dir::Read,
            addr: 512,
            len: 1,
        },
    ];
    sim.run(&txns);
    let first = sim.timing().clone();
    assert_eq!(first.row_hits + first.row_misses, first.axi_bursts);
    assert_eq!(first.turnarounds, 2);
    sim.reset();
    assert_eq!(sim.timing(), &cfa::memsim::Timing::default());
    sim.run(&txns);
    // a reset simulator replays the same stream to the same counters
    assert_eq!(sim.timing(), &first);
}

#[test]
fn prop_multiport_identities_hold_on_every_port() {
    // the engine identities are per-channel properties: each port of a
    // multi-port interface is an independent MemSim, so
    // `row_hits + row_misses == axi_bursts` must hold on every port, for
    // both routing policies, and the data bus of each port moves exactly
    // the beats routed to it
    prop_run("multiport per-port identities", Config::small(40), |g| {
        let cfg = MemConfig::default();
        let txns = random_txns(g, g.usize(1, 24));
        let ports = g.usize(2, 4);
        let maps = [
            PortMap::Interleaved {
                stripe_elems: 1 << g.usize(5, 9),
            },
            PortMap::ByRange {
                bounds: (0..ports as u64).map(|p| p * (1 << 18)).collect(),
            },
        ];
        for map in maps {
            let mut mp = MultiPortSim::new(cfg.clone(), ports, map);
            for t in &txns {
                mp.submit(t);
            }
            let timings = mp.timings();
            assert_eq!(timings.len(), ports);
            let mut beats_total = 0u64;
            for (p, t) in timings.iter().enumerate() {
                assert_eq!(t.row_hits + t.row_misses, t.axi_bursts, "port {p}: {t:?}");
                assert!(t.cycles >= t.data_cycles, "port {p}: {t:?}");
                beats_total += t.data_cycles;
            }
            // with elem_bytes == bus_bytes each element is one beat, and
            // routing splits transactions without changing their volume
            let elems: u64 = txns.iter().map(|t| t.len).sum();
            assert_eq!(beats_total, elems);
            // the aggregate clock is the slowest channel
            assert_eq!(mp.now(), mp.channel_times().into_iter().max().unwrap());
        }
    });
}

#[test]
fn prop_single_port_multiport_equals_serial_memsim() {
    // ports=1 must degenerate to the plain engine bit for bit: same
    // completion time, same counters — for any routing policy
    prop_run("multiport(1) == MemSim", Config::small(40), |g| {
        let cfg = MemConfig::default();
        let txns = random_txns(g, g.usize(1, 24));
        let mut serial = MemSim::new(cfg.clone());
        serial.run(&txns);
        let maps = [
            PortMap::Interleaved {
                stripe_elems: 1 << g.usize(3, 9),
            },
            PortMap::ByRange { bounds: vec![0] },
        ];
        for map in maps {
            let mut mp = MultiPortSim::new(cfg.clone(), 1, map);
            for t in &txns {
                mp.submit(t);
            }
            assert_eq!(mp.now(), serial.now());
            assert_eq!(mp.timings()[0], serial.timing());
        }
    });
}

#[test]
fn cfa_facet_port_map_keeps_identities_per_port() {
    use cfa::layout::cfa::Cfa;
    use cfa::layout::Allocation;
    use cfa::poly::deps::DepPattern;
    use cfa::poly::tiling::Tiling;
    // one facet stream per port: every port still satisfies the engine
    // identities while serving only its facet's address range
    let tiling = Tiling::new(vec![24, 24, 24], vec![8, 8, 8]);
    let deps =
        DepPattern::new(vec![vec![-1, 0, 0], vec![0, -1, 0], vec![0, 0, -2]]).unwrap();
    let cfa = Cfa::new(tiling.clone(), deps).unwrap();
    let ports = cfa.facet_arrays().len();
    let map = cfa_port_map(&cfa, ports);
    let mut mp = MultiPortSim::new(MemConfig::default(), ports, map);
    for coords in tiling.tiles() {
        let plan = cfa.plan(&coords);
        for r in &plan.read_runs {
            mp.submit(&Txn {
                dir: Dir::Read,
                addr: r.addr,
                len: r.len,
            });
        }
        for r in &plan.write_runs {
            mp.submit(&Txn {
                dir: Dir::Write,
                addr: r.addr,
                len: r.len,
            });
        }
    }
    let timings = mp.timings();
    assert_eq!(timings.len(), ports);
    for (p, t) in timings.iter().enumerate() {
        assert!(t.axi_bursts > 0, "port {p} never used");
        assert_eq!(t.row_hits + t.row_misses, t.axi_bursts, "port {p}: {t:?}");
    }
}

#[test]
fn measure_reports_all_observed_activates() {
    // Bandwidth::row_misses keeps its historical meaning: first-beat
    // misses plus mid-burst row switches
    let cfg = MemConfig {
        row_bytes: 1024, // rows below the AXI boundary -> mid-burst crossings
        ..MemConfig::default()
    };
    let mut sim = MemSim::new(cfg);
    let bw = sim.measure(
        &[Txn {
            dir: Dir::Read,
            addr: 0,
            len: 8192, // 64 KiB: many 1 KiB rows
        }],
        8192,
    );
    let t = sim.timing().clone();
    assert!(t.row_switches > 0, "{t:?}");
    assert_eq!(bw.row_misses, t.row_misses + t.row_switches);
    assert_eq!(t.row_hits + t.row_misses, t.axi_bursts);
}
