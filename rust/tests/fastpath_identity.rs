//! Fast-path identity properties — the oracles of the burst-grained hot
//! path. Two contracts, pinned across all four allocations, the Table-I
//! dependence patterns and random tilings:
//!
//! 1. **Run cursor ≡ pointwise addressing.** Concatenating the intervals
//!    `for_each_run` visits reproduces `[addr_of(array, p) for p in
//!    box.points()]` element for element, for every piece of every plan —
//!    so marshalling through slices is bit-identical to the per-point loop
//!    (same values, same fold order).
//! 2. **Memoized ≡ fresh planning.** `PlanCache::plan` equals
//!    `Allocation::plan` exactly — runs, pieces and counters — whether the
//!    tile is interior (rebased from the canonical plan) or boundary
//!    (fresh), on exact and non-exact tilings alike.

use cfa::coordinator::AllocKind;
use cfa::harness::workloads::{heat3d, table1};
use cfa::layout::PlanCache;
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::util::prop::{run as prop_run, Config, Gen};

/// Random tiling accepted by every allocation: tile edges above the facet
/// widths; exact with >= 3 tiles per axis when `exact` (the memoizable
/// shape), otherwise a ragged boundary.
fn random_tiling(g: &Gen, deps: &DepPattern, exact: bool) -> Tiling {
    let tile: Vec<i64> = deps
        .widths()
        .iter()
        .map(|w| w.max(&1) + g.i64(1, 3))
        .collect();
    let space: Vec<i64> = tile
        .iter()
        .map(|t| t * g.i64(3, 4) + if exact { 0 } else { 1 })
        .collect();
    Tiling::new(space, tile)
}

#[test]
fn prop_run_cursor_equals_pointwise_addr_of() {
    prop_run(
        "for_each_run ≡ per-point addr_of",
        Config::small(8),
        |g| {
            let wl = table1(true);
            let w = g.choose(&wl);
            let deps = DepPattern::new(w.deps.clone()).unwrap();
            let tiling = random_tiling(g, &deps, g.bool());
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                for tc in tiling.tiles() {
                    let plan = alloc.plan(&tc);
                    for pc in plan.read_pieces.iter().chain(&plan.write_pieces) {
                        let mut concat: Vec<u64> = Vec::new();
                        alloc.for_each_run(pc.array, &pc.iter_box, &mut |a, l| {
                            concat.extend(a..a + l)
                        });
                        let pointwise: Vec<u64> = pc
                            .iter_box
                            .points()
                            .map(|p| alloc.addr_of(pc.array, &p))
                            .collect();
                        assert_eq!(
                            concat,
                            pointwise,
                            "{}/{}: tile {tc:?} piece {pc:?}",
                            w.name,
                            kind.name()
                        );
                    }
                }
            }
        },
    );
}

#[test]
fn prop_memoized_plans_equal_fresh_plans() {
    prop_run(
        "PlanCache ≡ fresh planning",
        Config::small(8),
        |g| {
            let wl = table1(true);
            let w = g.choose(&wl);
            let deps = DepPattern::new(w.deps.clone()).unwrap();
            let tiling = random_tiling(g, &deps, g.bool());
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                let cache = PlanCache::new(alloc.as_ref());
                for tc in tiling.tiles() {
                    assert_eq!(
                        cache.plan(&tc),
                        alloc.plan(&tc),
                        "{}/{}: tile {tc:?}",
                        w.name,
                        kind.name()
                    );
                }
            }
        },
    );
}

#[test]
fn prop_streamed_write_locs_equal_vec_write_locs() {
    prop_run(
        "for_each_write_loc ≡ write_locs",
        Config::small(12),
        |g| {
            let wl = table1(true);
            let w = g.choose(&wl);
            let deps = DepPattern::new(w.deps.clone()).unwrap();
            let tiling = random_tiling(g, &deps, g.bool());
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                for _ in 0..20 {
                    let p: Vec<i64> = tiling
                        .space
                        .iter()
                        .map(|&n| g.i64(0, n - 1))
                        .collect();
                    let mut streamed: Vec<(usize, u64)> = Vec::new();
                    alloc.for_each_write_loc(&p, &mut |a, addr| streamed.push((a, addr)));
                    assert_eq!(
                        streamed,
                        alloc.write_locs(&p),
                        "{}/{}: {p:?}",
                        w.name,
                        kind.name()
                    );
                }
            }
        },
    );
}

#[test]
fn memoization_on_table1_sweep_tilings() {
    // the Fig-15 sweep shape: 16^3 tiles, 4 tiles per dim — real rebase
    // distances (not just the identity) on every Table-I pattern
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tile = vec![16i64, 16, 16];
        let tiling = Tiling::new(w.space_for(&tile, 4), tile);
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            let cache = PlanCache::new(alloc.as_ref());
            let mut interior = 0u64;
            for tc in tiling.tiles() {
                if cache.is_interior(&tc) {
                    interior += 1;
                }
                assert_eq!(
                    cache.plan(&tc),
                    alloc.plan(&tc),
                    "{}/{}: tile {tc:?}",
                    w.name,
                    kind.name()
                );
            }
            assert_eq!(interior, 8, "{}: 2^3 interior tiles", w.name);
        }
    }
}

#[test]
fn memoization_stays_exact_when_width_exceeds_tile() {
    // w > t: flow reaches past the immediate neighbor ring, so interior
    // tiles' flow regions are clipped by the space boundary differently —
    // the allocations must opt out of rebasing (CFA already rejects w > t
    // at construction) and the cache must still equal fresh planning
    let tiling = Tiling::new(vec![8, 8], vec![2, 2]);
    let deps = DepPattern::new(vec![vec![-3, 0], vec![0, -3]]).unwrap();
    for kind in [
        AllocKind::Original,
        AllocKind::BoundingBox,
        AllocKind::DataTiling,
    ] {
        let alloc = kind.build(&tiling, &deps).unwrap();
        let cache = PlanCache::new(alloc.as_ref());
        for tc in tiling.tiles() {
            assert_eq!(
                cache.plan(&tc),
                alloc.plan(&tc),
                "{}: tile {tc:?}",
                kind.name()
            );
        }
    }
}

#[test]
fn run_cursor_covers_4d_facets() {
    // §IV.J territory: 4-D spaces have the least contiguous pieces, so the
    // cursor's point-order contract is exercised hardest here
    let w = heat3d();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![8, 10, 10, 10], vec![4, 5, 5, 5]);
    let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
    for tc in tiling.tiles() {
        let plan = alloc.plan(&tc);
        for pc in plan.read_pieces.iter().chain(&plan.write_pieces) {
            let mut concat: Vec<u64> = Vec::new();
            alloc.for_each_run(pc.array, &pc.iter_box, &mut |a, l| concat.extend(a..a + l));
            let pointwise: Vec<u64> = pc
                .iter_box
                .points()
                .map(|p| alloc.addr_of(pc.array, &p))
                .collect();
            assert_eq!(concat, pointwise, "tile {tc:?}");
        }
    }
}
