//! Bit-identity and registry tests for the experiment session API.
//!
//! `Session::run` replaces the hand-wired driver entry points; these
//! tests pin it to the legacy paths it replaced: the batched coordinator
//! (timing counters, cycle totals **and** output buffers, across every
//! registered layout and random Table-I tilings), the figure-sweep
//! measurement, and the open-registry contract (a custom layout
//! registered by name is reachable from a spec with zero edits to
//! `coordinator/` or `harness/`).

use std::sync::Arc;

use cfa::coordinator::batch::{BatchCoordinator, Schedule};
use cfa::coordinator::{AllocKind, HostMemory};
use cfa::experiment::{ExperimentSpec, Mode, Report, ScheduleKind, Session};
use cfa::harness::figures;
use cfa::harness::workloads::{table1, Workload};
use cfa::layout::registry::names;
use cfa::layout::{AddrGenProfile, Allocation, LayoutRegistry, OriginalLayout, TilePlan};
use cfa::memsim::MemConfig;
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::util::prop::{run as prop_run, Config, Gen};

/// Random tiling that every allocation accepts: tile edges above the facet
/// widths, two-to-three tiles per axis (same family as batch_parallel.rs).
fn random_tiling(g: &Gen, deps: &DepPattern) -> Tiling {
    let tile: Vec<i64> = deps
        .widths()
        .iter()
        .map(|w| w.max(&1) + g.i64(1, 3))
        .collect();
    let space: Vec<i64> = tile.iter().map(|t| t * g.i64(2, 3)).collect();
    Tiling::new(space, tile)
}

fn session_for(
    w: &Workload,
    tiling: &Tiling,
    layout: &str,
    schedule: ScheduleKind,
    threads: usize,
) -> Session {
    ExperimentSpec::builder()
        .custom(w.name, tiling.space.clone(), tiling.tile.clone(), w.deps.clone())
        .layout(layout)
        .schedule(schedule)
        .threads(threads)
        .mem(MemConfig::default())
        .compile()
        .expect("compile session")
}

fn assert_buffers_bit_identical(a: &HostMemory, b: &HostMemory, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: footprint mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: buffers differ at {i} ({x} vs {y})"
        );
    }
}

/// Report ≡ BatchReport, field for field.
fn assert_report_matches_batch(
    rep: &Report,
    batch: &cfa::coordinator::batch::BatchReport,
    mem: &MemConfig,
    ctx: &str,
) {
    assert_eq!(rep.tiles, batch.tiles, "{ctx}: tiles");
    assert_eq!(rep.waves, batch.waves, "{ctx}: waves");
    assert_eq!(rep.makespan_cycles, batch.cycles, "{ctx}: cycles");
    assert_eq!(rep.timing.as_ref(), Some(&batch.timing), "{ctx}: timing");
    assert_eq!(rep.raw_bytes, batch.raw_elems * mem.elem_bytes, "{ctx}: raw");
    assert_eq!(
        rep.useful_bytes,
        batch.useful_elems * mem.elem_bytes,
        "{ctx}: useful"
    );
    assert_eq!(rep.transactions, batch.transactions, "{ctx}: txns");
}

#[test]
fn session_timing_and_sweep_match_batch_coordinator_all_layouts() {
    let wl = table1(true);
    let w = &wl[0];
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(w.space_for(&[16, 16, 16], 3), vec![16, 16, 16]);
    let mem = MemConfig::default();
    let reg = LayoutRegistry::with_builtins();
    for name in reg.names() {
        let alloc = AllocKind::parse(name).unwrap().build(&tiling, &deps).unwrap();
        for threads in [1usize, 4] {
            // Mode::Timing over the wavefront schedule
            let session = session_for(w, &tiling, name, ScheduleKind::Wavefront, threads);
            assert_eq!(session.layout(), name);
            let rep = session.run(Mode::Timing).unwrap();
            let sched = Schedule::wavefront(&tiling, &deps);
            let legacy = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                .threads(threads)
                .run_timing();
            assert_report_matches_batch(&rep, &legacy, &mem, &format!("{name}/timing/t{threads}"));

            // Mode::Sweep ≡ flat-schedule replay (Fig-15 rig)
            let sweep = session.run(Mode::Sweep).unwrap();
            let flat = Schedule::flat(&tiling);
            let legacy_flat = BatchCoordinator::new(alloc.as_ref(), &flat, mem.clone())
                .threads(threads)
                .run_timing();
            assert_report_matches_batch(
                &sweep,
                &legacy_flat,
                &mem,
                &format!("{name}/sweep/t{threads}"),
            );

            // the figure-sweep measurement returns exactly the session's
            // numbers
            let p = figures::measure_bandwidth_named(w, &tiling.tile, name, &mem, 3, threads, &reg)
                .unwrap();
            assert_eq!(p.alloc, name);
            assert_eq!(p.transactions, sweep.transactions, "{name}");
            assert_eq!(p.raw_bytes, sweep.raw_bytes);
            assert_eq!(p.raw_mb_s.to_bits(), sweep.raw_mb_s.to_bits(), "{name}");
            assert_eq!(
                p.effective_mb_s.to_bits(),
                sweep.effective_mb_s.to_bits(),
                "{name}"
            );
        }
    }
}

#[test]
fn prop_session_data_bit_identical_to_coordinator_on_random_tilings() {
    prop_run(
        "Session::run(Data) == BatchCoordinator::run_data",
        Config::small(6),
        |g| {
            let wl = table1(true);
            let w = g.choose(&wl);
            let deps = DepPattern::new(w.deps.clone()).unwrap();
            let tiling = random_tiling(g, &deps);
            let threads = g.usize(2, 5);
            let seed = g.i64(0, 1 << 30) as u64;
            let mem = MemConfig::default();
            let sched = Schedule::wavefront(&tiling, &deps);
            let reg = LayoutRegistry::with_builtins();
            for name in reg.names() {
                let session = session_for(w, &tiling, name, ScheduleKind::Wavefront, threads);
                let (rep, host) = session.run_data_buffered(seed).unwrap();
                assert_eq!(rep.mode, "data");
                let alloc = AllocKind::parse(name).unwrap().build(&tiling, &deps).unwrap();
                let (legacy, legacy_host) =
                    BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                        .threads(threads)
                        .run_data(seed);
                let ctx = format!("{name}/{:?}/t{threads}", tiling.tile);
                assert_report_matches_batch(&rep, &legacy, &mem, &ctx);
                assert_buffers_bit_identical(&host, &legacy_host, &ctx);
                // Mode::Data through run() drops the buffer but keeps the report
                let rep2 = session.run(Mode::Data { seed }).unwrap();
                assert_eq!(rep2.makespan_cycles, rep.makespan_cycles, "{ctx}");
                assert_eq!(rep2.timing, rep.timing, "{ctx}");
            }
        },
    );
}

/// A toy layout: the original row-major layout under a new name —
/// registered purely through the public registry API, no `coordinator/`
/// or `harness/` edits.
struct ToyLayout(OriginalLayout);

impl Allocation for ToyLayout {
    fn name(&self) -> &str {
        "toy"
    }
    fn tiling(&self) -> &Tiling {
        self.0.tiling()
    }
    fn footprint(&self) -> u64 {
        self.0.footprint()
    }
    fn num_arrays(&self) -> usize {
        self.0.num_arrays()
    }
    fn holds(&self, array: usize, p: &[i64]) -> bool {
        self.0.holds(array, p)
    }
    fn addr_of(&self, array: usize, p: &[i64]) -> u64 {
        self.0.addr_of(array, p)
    }
    fn plan(&self, coords: &[i64]) -> TilePlan {
        self.0.plan(coords)
    }
    fn read_loc(&self, p: &[i64]) -> (usize, u64) {
        self.0.read_loc(p)
    }
    fn write_locs(&self, p: &[i64]) -> Vec<(usize, u64)> {
        self.0.write_locs(p)
    }
    fn addrgen(&self) -> AddrGenProfile {
        self.0.addrgen()
    }
}

fn toy_registry() -> LayoutRegistry {
    let mut reg = LayoutRegistry::with_builtins();
    reg.register(
        "toy",
        &["toy-alias"],
        Arc::new(|t: &Tiling, d: &DepPattern| {
            Ok(Box::new(ToyLayout(OriginalLayout::new(t.clone(), d.clone())))
                as Box<dyn Allocation>)
        }),
    )
    .unwrap();
    reg
}

#[test]
fn registered_custom_layout_is_reachable_from_spec_by_name() {
    let wl = table1(true);
    let w = &wl[0];
    let tile = vec![8i64, 8, 8];
    let tiling = Tiling::new(w.space_for(&tile, 3), tile.clone());
    let reg = toy_registry();
    assert!(reg.names().contains(&"toy"));
    assert_eq!(reg.canonical("toy-alias"), Some("toy"));

    // spec-by-name through the alias, against the custom registry
    let session = ExperimentSpec::builder()
        .custom(w.name, tiling.space.clone(), tiling.tile.clone(), w.deps.clone())
        .layout("toy-alias")
        .schedule(ScheduleKind::Wavefront)
        .registry(reg.clone())
        .compile()
        .unwrap();
    assert_eq!(session.layout(), "toy");
    assert_eq!(session.allocation().name(), "toy");

    // the toy delegates to the original layout, so its run must equal the
    // original layout's run counter for counter
    let toy_rep = session.run(Mode::Timing).unwrap();
    let orig_rep = ExperimentSpec::builder()
        .custom(w.name, tiling.space.clone(), tiling.tile.clone(), w.deps.clone())
        .layout(names::ORIGINAL)
        .schedule(ScheduleKind::Wavefront)
        .registry(reg.clone())
        .compile()
        .unwrap()
        .run(Mode::Timing)
        .unwrap();
    assert_eq!(toy_rep.layout, "toy");
    assert_eq!(toy_rep.makespan_cycles, orig_rep.makespan_cycles);
    assert_eq!(toy_rep.timing, orig_rep.timing);
    assert_eq!(toy_rep.transactions, orig_rep.transactions);

    // and the figure sweep picks the new layout up with no harness edits
    let pts = figures::fig15_sweep_registry(&reg, &wl[..1], &MemConfig::default(), 2, 2);
    assert_eq!(pts.len(), wl[0].tile_sizes.len() * reg.len());
    assert!(pts.iter().any(|p| p.alloc == "toy"), "toy missing from sweep");
}

#[test]
fn unknown_spec_layout_error_names_the_registry() {
    let wl = table1(true);
    let w = &wl[0];
    let err = ExperimentSpec::builder()
        .custom(w.name, vec![24, 24, 24], vec![8, 8, 8], w.deps.clone())
        .layout("not-a-layout")
        .compile()
        .unwrap_err()
        .to_string();
    assert!(err.contains("not-a-layout"), "{err}");
    assert!(err.contains(names::CFA), "{err}");
}

#[test]
fn global_registry_backs_named_workload_sessions() {
    // the global registry resolves aliases for spec-by-name sessions
    let session = ExperimentSpec::builder()
        .named("jacobi2d5p", vec![8, 8, 8], 3)
        .layout("data-tiling")
        .compile()
        .unwrap();
    assert_eq!(session.layout(), names::DATATILE);
    let rep = session.run(Mode::Timing).unwrap();
    assert_eq!(rep.benchmark, "jacobi2d5p");
    assert_eq!(rep.tiles, session.tiling().num_tiles());
}

#[test]
fn report_json_survives_a_round_trip() {
    let wl = table1(true);
    let w = &wl[0];
    let tiling = Tiling::new(w.space_for(&[8, 8, 8], 3), vec![8, 8, 8]);
    let session = session_for(w, &tiling, names::CFA, ScheduleKind::Wavefront, 1);
    let rep = session.run(Mode::Timing).unwrap();
    let text = rep.to_json().to_string_pretty();
    let back = Report::from_json(&cfa::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.benchmark, rep.benchmark);
    assert_eq!(back.layout, rep.layout);
    assert_eq!(back.mode, rep.mode);
    assert_eq!(back.tiles, rep.tiles);
    assert_eq!(back.waves, rep.waves);
    assert_eq!(back.makespan_cycles, rep.makespan_cycles);
    assert_eq!(back.raw_bytes, rep.raw_bytes);
    assert_eq!(back.useful_bytes, rep.useful_bytes);
    assert_eq!(back.transactions, rep.transactions);
    assert_eq!(back.raw_mb_s.to_bits(), rep.raw_mb_s.to_bits());
    assert_eq!(back.timing, rep.timing);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn e2e_data_mode_reports_disabled_runtime_but_timing_works_offline() {
    use cfa::coordinator::reference::StencilKind;
    let session = ExperimentSpec::builder()
        .stencil(
            "jacobi2d5p_t4x16x16",
            StencilKind::Jacobi5p,
            vec![4, 16, 16],
            24,
            24,
            8,
        )
        .layout(names::CFA)
        .compile()
        .unwrap();
    // timing mode never touches the runtime
    let rep = session.run(Mode::Timing).unwrap();
    assert_eq!(rep.tiles, session.tiling().num_tiles());
    // the data mode needs PJRT, which the offline build stubs out
    let err = format!("{:#}", session.run(Mode::Data { seed: 1 }).unwrap_err());
    assert!(err.contains("pjrt"), "{err}");
    // the synthetic-kernel entry point refuses e2e sessions outright: it
    // would otherwise fabricate a plausible-looking unverified "data" run
    let err = session.run_data_buffered(1).unwrap_err().to_string();
    assert!(err.contains("end-to-end"), "{err}");
}

#[cfg(feature = "pjrt")]
mod e2e {
    //! With the runtime available, the end-to-end data path must be fully
    //! deterministic: two sessions compiled from the same spec replay to
    //! the same counters and the same verification error, bit for bit.
    use super::*;
    use cfa::coordinator::reference::StencilKind;
    use cfa::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::open(dir).expect("open artifacts"))
        } else {
            eprintln!("artifacts/ missing - skipping e2e determinism test");
            None
        }
    }

    #[test]
    fn stencil_session_runs_are_deterministic() {
        let Some(rt) = runtime() else { return };
        let mem = MemConfig {
            elem_bytes: 4,
            ..MemConfig::default()
        };
        for kind in AllocKind::ALL {
            let compile = || {
                ExperimentSpec::builder()
                    .stencil(
                        "jacobi2d5p_t4x16x16",
                        StencilKind::Jacobi5p,
                        vec![4, 16, 16],
                        24,
                        24,
                        8,
                    )
                    .layout(kind.name())
                    .mem(mem.clone())
                    .compile()
                    .expect("compile")
            };
            let a = compile()
                .run_with_runtime(&rt, Mode::Data { seed: 11 })
                .expect("session run");
            let b = compile()
                .run_with_runtime(&rt, Mode::Data { seed: 11 })
                .expect("session run");
            assert_eq!(a.benchmark, b.benchmark, "{}", kind.name());
            assert_eq!(a.layout, b.layout);
            assert_eq!(a.tiles, b.tiles);
            assert_eq!(a.makespan_cycles, b.makespan_cycles);
            assert_eq!(a.mem_busy_cycles, b.mem_busy_cycles);
            assert_eq!(a.raw_bytes, b.raw_bytes);
            assert_eq!(a.useful_bytes, b.useful_bytes);
            assert_eq!(a.transactions, b.transactions);
            assert_eq!(
                a.max_abs_err.unwrap().to_bits(),
                b.max_abs_err.unwrap().to_bits()
            );
        }
    }
}
