//! Property tests for the batched tile coordinator: the parallel executor
//! must be **bit-identical** to serial execution — `Timing` counters,
//! cycle totals and output buffers — across all four allocations, random
//! Table-I dependence patterns, random tilings and random worker counts.
//! Also checks that wave-synchronous execution equals plain sequential
//! tile-at-a-time execution (the scheduler's correctness argument).

use cfa::coordinator::batch::{execute_tile, plan_tiles, BatchCoordinator, Schedule};
use cfa::coordinator::{AllocKind, HostMemory};
use cfa::harness::workloads::table1;
use cfa::layout::Allocation;
use cfa::memsim::MemConfig;
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;
use cfa::util::prop::{run as prop_run, Config, Gen};

/// Random tiling that every allocation accepts: tile edges above the facet
/// widths, two-to-three tiles per axis.
fn random_tiling(g: &Gen, deps: &DepPattern) -> Tiling {
    let tile: Vec<i64> = deps
        .widths()
        .iter()
        .map(|w| w.max(&1) + g.i64(1, 3))
        .collect();
    let space: Vec<i64> = tile.iter().map(|t| t * g.i64(2, 3)).collect();
    Tiling::new(space, tile)
}

fn assert_buffers_bit_identical(a: &HostMemory, b: &HostMemory, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: footprint mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: buffers differ at {i} ({x} vs {y})"
        );
    }
}

#[test]
fn prop_parallel_bit_identical_to_serial_on_table1() {
    prop_run(
        "batch parallel == serial (timing + buffers)",
        Config::small(8),
        |g| {
            let wl = table1(true);
            let w = g.choose(&wl);
            let deps = DepPattern::new(w.deps.clone()).unwrap();
            let tiling = random_tiling(g, &deps);
            let sched = Schedule::wavefront(&tiling, &deps);
            let threads = g.usize(2, 6);
            let seed = g.i64(0, 1 << 30) as u64;
            let mem = MemConfig::default();
            for kind in AllocKind::ALL {
                let alloc = kind.build(&tiling, &deps).unwrap();
                let serial =
                    BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone()).run_data(seed);
                let par = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                    .threads(threads)
                    .run_data(seed);
                let ctx = format!("{}/{:?} threads={threads}", kind.name(), tiling.tile);
                assert_eq!(serial.0, par.0, "{ctx}: report");
                assert_buffers_bit_identical(&serial.1, &par.1, &ctx);
                // timing-only path agrees with the data path's accounting
                let timing_only = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                    .threads(threads)
                    .run_timing();
                assert_eq!(timing_only, serial.0, "{ctx}: run_timing");
            }
        },
    );
}

#[test]
fn prop_wavefront_schedule_is_a_permutation_with_safe_waves() {
    prop_run("wavefront schedule validity", Config::small(12), |g| {
        let wl = table1(true);
        let w = g.choose(&wl);
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tiling = random_tiling(g, &deps);
        let sched = Schedule::wavefront(&tiling, &deps);
        assert_eq!(sched.num_tiles(), tiling.num_tiles());
        // each tile exactly once
        let mut seen: Vec<Vec<i64>> = sched.waves().iter().flatten().cloned().collect();
        seen.sort();
        let mut all: Vec<Vec<i64>> = tiling.tiles().collect();
        all.sort();
        assert_eq!(seen, all, "{}", w.name);
        // producers strictly earlier
        let wave_of = |c: &Vec<i64>| sched.waves().iter().position(|wv| wv.contains(c)).unwrap();
        for coords in tiling.tiles() {
            let wc = wave_of(&coords);
            for (p, _) in cfa::poly::flow::producer_tiles(&tiling, &deps, &coords) {
                assert!(wave_of(&p) < wc, "{}: {p:?} !< {coords:?}", w.name);
            }
        }
    });
}

#[test]
fn wave_synchronous_equals_sequential_tile_at_a_time() {
    // The scheduler's whole point: executing wave-by-wave (gather against
    // pre-wave memory) must produce the same buffers as the classic
    // sequential loop that writes each tile's output immediately.
    let mem = MemConfig::default();
    for w in table1(true) {
        let deps = DepPattern::new(w.deps.clone()).unwrap();
        let tile: Vec<i64> = deps.widths().iter().map(|wd| wd.max(&1) + 2).collect();
        let space: Vec<i64> = tile.iter().map(|t| t * 3).collect();
        let tiling = Tiling::new(space, tile);
        let sched = Schedule::wavefront(&tiling, &deps);
        let seed = 0xC0FFEE;
        for kind in AllocKind::ALL {
            let alloc = kind.build(&tiling, &deps).unwrap();
            // sequential reference: immediate writes, lexicographic order
            let mut host = HostMemory::new(alloc.footprint());
            for coords in tiling.tiles() {
                let plan = alloc.plan(&coords);
                for (addr, v) in execute_tile(alloc.as_ref(), &plan, &host, seed) {
                    host.write(addr, v);
                }
            }
            let (report, batched) = BatchCoordinator::new(alloc.as_ref(), &sched, mem.clone())
                .threads(4)
                .run_data(seed);
            assert_eq!(report.tiles, tiling.num_tiles());
            assert_buffers_bit_identical(&host, &batched, &format!("{}/{}", w.name, kind.name()));
        }
    }
}

#[test]
fn plan_tiles_matches_per_tile_planning() {
    // the drivers' parallel planning path returns exactly alloc.plan(tile)
    let w = &table1(true)[0];
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![12, 12, 12], vec![4, 4, 4]);
    let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
    let tiles: Vec<Vec<i64>> = tiling.tiles().collect();
    let par = plan_tiles(alloc.as_ref(), &tiles, 4);
    assert_eq!(par.len(), tiles.len());
    for (coords, plan) in tiles.iter().zip(&par) {
        let serial = alloc.plan(coords);
        assert_eq!(serial.read_runs, plan.read_runs, "{coords:?}");
        assert_eq!(serial.write_runs, plan.write_runs, "{coords:?}");
        assert_eq!(serial.read_useful, plan.read_useful);
        assert_eq!(serial.write_useful, plan.write_useful);
    }
}

#[test]
fn flat_schedule_timing_matches_wavefront_totals() {
    // same plans, same per-tile submit order inside a wave; only the wave
    // grouping differs — conserved quantities must agree even though
    // cycle-level interleaving may not
    let w = &table1(true)[0];
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![12, 12, 12], vec![4, 4, 4]);
    let alloc = AllocKind::Cfa.build(&tiling, &deps).unwrap();
    let mem = MemConfig::default();
    let flat = Schedule::flat(&tiling);
    let wavy = Schedule::wavefront(&tiling, &deps);
    let a = BatchCoordinator::new(alloc.as_ref(), &flat, mem.clone()).run_timing();
    let b = BatchCoordinator::new(alloc.as_ref(), &wavy, mem.clone()).run_timing();
    assert_eq!(a.tiles, b.tiles);
    assert_eq!(a.raw_elems, b.raw_elems);
    assert_eq!(a.useful_elems, b.useful_elems);
    assert_eq!(a.transactions, b.transactions);
    assert_eq!(a.timing.data_cycles, b.timing.data_cycles);
    assert_eq!(a.timing.axi_bursts, b.timing.axi_bursts);
}
