//! Fig 15 regeneration: raw + effective bandwidth for every allocation ×
//! benchmark × tile size, on the simulated ZC706 HP0 port (800 MB/s
//! roofline, f64 elements — the paper's exact rig).
//!
//! Run: `cargo bench --bench fig15_bandwidth [-- --quick]`
//! Writes bench_results/fig15.csv and prints the stacked-bar panels.

use cfa::harness::{figures, workloads};
use cfa::memsim::MemConfig;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let wl = workloads::table1(quick);
    let mem = MemConfig::default();
    eprintln!(
        "fig15: {} benchmarks x {} tile sizes x 4 allocations (quick={quick})",
        wl.len(),
        wl[0].tile_sizes.len()
    );
    let t0 = std::time::Instant::now();
    let pts = figures::fig15_sweep(&wl, &mem, 3);
    for w in &wl {
        print!("{}", figures::render_fig15(&pts, w.name, &mem));
    }
    std::fs::create_dir_all("bench_results").ok();
    cfa::util::fsx::write_atomic("bench_results/fig15.csv", figures::fig15_csv(&pts)).ok();
    cfa::util::fsx::write_atomic(
        "bench_results/fig15.json",
        figures::fig15_json(&pts, &mem).to_string_pretty(),
    )
    .ok();
    // headline summary: best effective bandwidth per allocation, for
    // every layout in the registry (a newly registered layout shows up
    // here with no edits)
    println!("summary (effective bandwidth as % of the 800 MB/s roofline):");
    let reg = cfa::layout::registry::global();
    for alloc in reg.names() {
        let effs: Vec<f64> = pts
            .iter()
            .filter(|p| p.alloc == alloc)
            .map(|p| 100.0 * p.effective_mb_s / mem.peak_mb_s())
            .collect();
        let avg = effs.iter().sum::<f64>() / effs.len().max(1) as f64;
        let max = effs.iter().cloned().fold(0.0, f64::max);
        println!("  {alloc:<9} mean {avg:5.1}%   best {max:5.1}%");
    }
    println!(
        "\n{} points in {:.1}s -> bench_results/fig15.csv",
        pts.len(),
        t0.elapsed().as_secs_f64()
    );
}
