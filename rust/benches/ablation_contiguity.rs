//! Ablation: how much each CFA contiguity level contributes (§IV.G–I).
//! Toggles inter-tile merging, the intra-tile facet choice, and the Fig-11
//! over-approximation on jacobi2d9p-gol (the deepest pattern, w = 2,2,2)
//! and reports transactions + bandwidth per configuration.
//!
//! Run: `cargo bench --bench ablation_contiguity`

use cfa::harness::workloads;
use cfa::layout::cfa::{Cfa, CfaOpts};
use cfa::layout::Allocation;
use cfa::memsim::{Dir, MemConfig, MemSim, Txn};
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;

fn measure(tiling: &Tiling, deps: &DepPattern, opts: CfaOpts, mem: &MemConfig) -> (u64, f64, f64) {
    let cfa = Cfa::with_opts(tiling.clone(), deps.clone(), opts).unwrap();
    let mut sim = MemSim::new(mem.clone());
    let (mut raw, mut useful, mut txns) = (0u64, 0u64, 0u64);
    for coords in tiling.tiles() {
        let plan = cfa.plan(&coords);
        for r in plan.read_runs.iter() {
            sim.submit(&Txn { dir: Dir::Read, addr: r.addr, len: r.len });
        }
        for r in plan.write_runs.iter() {
            sim.submit(&Txn { dir: Dir::Write, addr: r.addr, len: r.len });
        }
        raw += plan.read_raw() + plan.write_raw();
        useful += plan.read_useful + plan.write_useful;
        txns += plan.transactions() as u64;
    }
    let secs = mem.secs(sim.now().max(1));
    (
        txns,
        raw as f64 * mem.elem_bytes as f64 / 1e6 / secs,
        useful as f64 * mem.elem_bytes as f64 / 1e6 / secs,
    )
}

fn main() {
    let w = workloads::by_name("jacobi2d9p-gol").unwrap();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let mem = MemConfig::default();
    println!("ablation on {} (widths {:?}), tile 32x32x32, 3^3 tiles\n", w.name, deps.widths());
    println!(
        "{:<34} {:>8} {:>10} {:>10}",
        "configuration", "txns", "raw MB/s", "eff MB/s"
    );
    let tiling = Tiling::new(w.space_for(&[32, 32, 32], 3), vec![32, 32, 32]);
    let configs = [
        ("full CFA (inter+intra+overapprox)", CfaOpts { inter_tile: true, intra_tile: true, bbox_expand: true }),
        ("no inter-tile merging", CfaOpts { inter_tile: false, intra_tile: true, bbox_expand: true }),
        ("no intra-tile facet choice", CfaOpts { inter_tile: true, intra_tile: false, bbox_expand: true }),
        ("no Fig-11 over-approximation", CfaOpts { inter_tile: true, intra_tile: true, bbox_expand: false }),
        ("full-tile contiguity only", CfaOpts { inter_tile: false, intra_tile: false, bbox_expand: false }),
    ];
    for (name, opts) in configs {
        let (txns, raw, eff) = measure(&tiling, &deps, opts, &mem);
        println!("{name:<34} {txns:>8} {raw:>10.1} {eff:>10.1}");
    }
}
