//! Explorer scaling: model-guided search + early-abort replay vs the
//! exhaustive full-replay baseline, and sharded exploration folded back
//! with `journal::merge`.
//!
//! Run: `cargo bench --bench explorer_scaling [-- --smoke] [-- --out PATH]`
//!
//! Every run first asserts the scaling identities (verification tier 12):
//! the pruned model-guided front is byte-identical to the exhaustive
//! front with strictly fewer full replays (the `pruned` counter proves
//! it), and a 2-shard run merged under the space's enumeration order
//! reproduces the unsharded journal file byte for byte. Then it records
//! machine-readable results to `BENCH_dse.json` at the repo root
//! (override with `--out`). `--smoke` runs check the rig, not the
//! numbers: without an explicit `--out` they write
//! `BENCH_dse.smoke.json`, so a CI smoke pass can never clobber real
//! recorded results.

use std::path::PathBuf;

use cfa::dse::{journal, Evaluation, Exhaustive, Explorer, MemVariant, ModelGuided, Point, Space};
use cfa::layout::registry;
use cfa::memsim::MemConfig;
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("points_per_s", Json::num(e)));
    }
    Json::obj(fields)
}

fn render_sorted(evals: &[Evaluation]) -> Vec<String> {
    let mut v: Vec<String> = evals.iter().map(|e| e.to_json().to_string_compact()).collect();
    v.sort();
    v
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dse.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dse.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut results: Vec<Measurement> = Vec::new();

    // the tiny space, widened with the HBM-like geometry off-smoke so the
    // exploration has more than one memory to rank across
    let mut space = Space::builtin("tiny").unwrap();
    if !smoke {
        space.mems = vec![
            MemVariant::paper_default(),
            MemVariant::new("hbm", MemConfig::preset("hbm").unwrap()),
        ];
    }
    let reg = registry::global();
    let points = space.enumerate(&reg).unwrap();
    let total = points.len();
    let n_points = total as u64;

    // ---- identity gate 1: exhaustive reference (journaled for the merge
    // comparison below)
    let unsharded_journal = tmp("cfa_bench_dse_unsharded.jsonl");
    let reference = Explorer::new(space.clone(), Box::new(Exhaustive::new()))
        .journal(&unsharded_journal)
        .explore()
        .unwrap();
    assert_eq!(reference.evaluated, total);

    // the warm-start rows a resumed campaign would hand the model: every
    // scored point of a prior run
    let warm_rows: Vec<(Point, f64)> = reference
        .all
        .iter()
        .map(|e| (e.point().clone(), e.effective_mb_s()))
        .collect();

    // ---- identity gate 2: model-guided + early abort lands on the same
    // front with strictly fewer full replays
    let guided = Explorer::new(
        space.clone(),
        Box::new(ModelGuided::new(42).with_warm_start(warm_rows.clone())),
    )
    .prune(true)
    .explore()
    .unwrap();
    assert_eq!(
        render_sorted(&reference.front),
        render_sorted(&guided.front),
        "early abort changed the surviving front"
    );
    assert_eq!(
        guided.evaluated + guided.pruned,
        reference.evaluated,
        "every point must be attempted, as a replay or a prune"
    );
    assert!(
        guided.pruned > 0,
        "early abort never fired: model-guided ran {} full replays, \
         same as exhaustive",
        guided.evaluated
    );
    let full = render_sorted(&reference.all);
    for e in &guided.all {
        assert!(
            full.contains(&e.to_json().to_string_compact()),
            "{} completed with different bytes under pruning",
            e.fingerprint()
        );
    }
    println!(
        "identity: pruned model-guided front == exhaustive front \
         ({} full replays instead of {}, {} pruned)",
        guided.evaluated, reference.evaluated, guided.pruned
    );

    // ---- identity gate 3: 2-shard explore + merge reproduces the
    // unsharded journal byte for byte
    let shards = 2usize;
    let shard_paths: Vec<PathBuf> = (0..shards)
        .map(|i| {
            let p = tmp(&format!("cfa_bench_dse_shard{i}.jsonl"));
            let out = Explorer::new(space.clone(), Box::new(Exhaustive::new()))
                .shard(i, shards)
                .journal(&p)
                .explore()
                .unwrap();
            assert_eq!(out.evaluated + out.sharded_out, total, "shard {i}");
            p
        })
        .collect();
    let merged = tmp("cfa_bench_dse_merged.jsonl");
    let stats = journal::merge(&merged, &shard_paths, Some(&points)).unwrap();
    assert_eq!(stats.written, total);
    assert_eq!(stats.duplicates, 0);
    assert_eq!(
        std::fs::read_to_string(&unsharded_journal).unwrap(),
        std::fs::read_to_string(&merged).unwrap(),
        "merged shard journals differ from the unsharded run's"
    );
    println!("identity: {shards}-shard merge == unsharded journal ({total} records)");

    // ---- measurements
    results.push(
        b.bench("explore exhaustive (full replays)", || {
            black_box(
                Explorer::new(space.clone(), Box::new(Exhaustive::new()))
                    .explore()
                    .unwrap(),
            );
        })
        .with_work(n_points, n_points),
    );
    let m_exhaustive = results.last().unwrap().summary.median;
    results.push(
        b.bench("explore model-guided + prune (warm model)", || {
            black_box(
                Explorer::new(
                    space.clone(),
                    Box::new(ModelGuided::new(42).with_warm_start(warm_rows.clone())),
                )
                .prune(true)
                .explore()
                .unwrap(),
            );
        })
        .with_work(n_points, n_points),
    );
    let m_guided = results.last().unwrap().summary.median;
    results.push(
        b.bench("explore model-guided cold (no warm start)", || {
            black_box(
                Explorer::new(space.clone(), Box::new(ModelGuided::new(42)))
                    .prune(true)
                    .explore()
                    .unwrap(),
            );
        })
        .with_work(n_points, n_points),
    );
    results.push(
        b.bench("merge 2 shard journals", || {
            let out = std::env::temp_dir().join("cfa_bench_dse_merge_iter.jsonl");
            black_box(journal::merge(&out, &shard_paths, Some(&points)).unwrap());
        })
        .with_work(n_points, n_points),
    );

    let prune_speedup = m_exhaustive / m_guided;
    println!("\nexplorer-scaling benchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
    println!(
        "\nspeedups: model-guided + early abort {prune_speedup:.2}x over \
         exhaustive ({} of {} replays pruned)",
        guided.pruned, total
    );

    let json = Json::obj(vec![
        ("bench", Json::str("explorer_scaling")),
        ("smoke", Json::Bool(smoke)),
        (
            "workload",
            Json::obj(vec![
                ("space", Json::str("tiny")),
                ("mems", Json::num(space.mems.len().max(1) as f64)),
                ("points", Json::num(total as f64)),
                ("shards", Json::num(shards as f64)),
            ]),
        ),
        (
            "counters",
            Json::obj(vec![
                ("full_replays_exhaustive", Json::num(reference.evaluated as f64)),
                ("full_replays_model_guided", Json::num(guided.evaluated as f64)),
                ("pruned_replays", Json::num(guided.pruned as f64)),
            ]),
        ),
        (
            "speedups",
            Json::obj(vec![(
                "model_guided_prune_vs_exhaustive",
                Json::num(prune_speedup),
            )]),
        ),
        ("identity_asserted", Json::Bool(true)),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    std::fs::remove_file(&unsharded_journal).ok();
    std::fs::remove_file(&merged).ok();
    for p in &shard_paths {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_file(std::env::temp_dir().join("cfa_bench_dse_merge_iter.jsonl")).ok();
}
