//! Trace-replay throughput: the memory simulator's scalar `Txn`-list path
//! vs compiled-trace replay (scalar and coalesced-streaming), and the
//! `cfa tune` evaluation loop cold vs warm trace cache.
//!
//! Run: `cargo bench --bench replay_throughput [-- --smoke] [-- --out PATH]`
//!
//! Every run first asserts the fast paths **bit-identical** to the scalar
//! engine (full `ReplayState` snapshots and session reports), then records
//! machine-readable results to `BENCH_replay.json` at the repo root
//! (override with `--out`). `--smoke` runs check the rig, not the numbers:
//! without an explicit `--out` they write `BENCH_replay.smoke.json`, so a
//! CI smoke pass can never clobber real recorded results.

use std::sync::Arc;

use cfa::dse::{Evaluator, MemVariant, Space};
use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind, Session};
use cfa::layout::registry;
use cfa::memsim::{Dir, MemConfig, MemSim, TraceCache, Txn, TxnTrace};
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("elems_per_s", Json::num(e)));
    }
    if let Some(r) = m.runs_per_sec() {
        fields.push(("bursts_per_s", Json::num(r)));
    }
    Json::obj(fields)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_replay.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_replay.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut results: Vec<Measurement> = Vec::new();
    let cfg = MemConfig::default();

    // ---- geometry set: the dse evaluator's shape (flat schedule) over
    // every registered layout
    let tile = vec![32i64, 32, 32];
    let tiles_per_dim = if smoke { 3 } else { 4 };
    let reg = registry::global();
    let sessions: Vec<Session> = reg
        .names()
        .iter()
        .map(|&name| {
            ExperimentSpec::builder()
                .named("jacobi2d5p", tile.clone(), tiles_per_dim)
                .layout(name)
                .schedule(ScheduleKind::Flat)
                .mem(cfg.clone())
                .registry(reg.clone())
                .compile()
                .expect("compile session")
        })
        .collect();

    // identity gate: trace replay (streamed and scalar) == Txn-list replay
    // == Mode::Timing, for every session, full state compared
    let mut traces: Vec<TxnTrace> = Vec::new();
    let mut txn_lists: Vec<Vec<Txn>> = Vec::new();
    let (mut total_bursts, mut total_elems) = (0u64, 0u64);
    for session in &sessions {
        let direct = session.run(Mode::Timing).expect("timing run");
        let trace = session.compile_trace();
        let txns = trace.txns();
        let mut by_list = MemSim::new(cfg.clone());
        by_list.run(&txns);
        let mut by_trace = MemSim::new(cfg.clone());
        by_trace.run_trace(&trace);
        let mut by_trace_scalar = MemSim::new(cfg.clone());
        by_trace_scalar.run_trace_scalar(&trace);
        assert!(by_trace.streaming_enabled());
        assert_eq!(by_list.snapshot(), by_trace.snapshot(), "{}", session.layout());
        assert_eq!(by_list.snapshot(), by_trace_scalar.snapshot());
        let replayed = session.run_trace(&trace).expect("trace run");
        assert_eq!(replayed.timing, direct.timing, "{}", session.layout());
        assert_eq!(replayed.makespan_cycles, direct.makespan_cycles);
        total_bursts += by_list.timing().axi_bursts;
        total_elems += trace.total_elems();
        traces.push(trace);
        txn_lists.push(txns);
    }
    println!(
        "identity: trace replay == scalar engine across {} layouts \
         ({total_bursts} AXI bursts)",
        sessions.len()
    );

    results.push(
        b.bench("replay txn-list (scalar submit loop)", || {
            for txns in &txn_lists {
                let mut sim = MemSim::new(cfg.clone());
                black_box(sim.run(txns));
            }
        })
        .with_work(total_elems, total_bursts),
    );
    results.push(
        b.bench("replay trace (scalar)", || {
            for trace in &traces {
                let mut sim = MemSim::new(cfg.clone());
                black_box(sim.run_trace_scalar(trace));
            }
        })
        .with_work(total_elems, total_bursts),
    );
    let m_trace_scalar = results.last().unwrap().summary.median;
    results.push(
        b.bench("replay trace (coalesced streaming)", || {
            for trace in &traces {
                let mut sim = MemSim::new(cfg.clone());
                black_box(sim.run_trace(trace));
            }
        })
        .with_work(total_elems, total_bursts),
    );
    let m_trace_streamed = results.last().unwrap().summary.median;

    // ---- the streaming kernel's home turf: long contiguous spans
    let long: Vec<Txn> = (0..if smoke { 8 } else { 64 })
        .map(|i| Txn {
            dir: Dir::Read,
            addr: i * (1 << 18),
            len: 1 << 17, // 1 MiB contiguous at 8 B/elem
        })
        .collect();
    let long_trace = {
        let mut t = TxnTrace::new();
        for x in &long {
            t.push(x.dir, x.addr, x.len);
        }
        t
    };
    let long_bursts = {
        let mut a = MemSim::new(cfg.clone());
        a.run(&long);
        let mut s = MemSim::new(cfg.clone());
        s.run_trace(&long_trace);
        assert_eq!(a.snapshot(), s.snapshot(), "long-span identity");
        a.timing().axi_bursts
    };
    let long_elems = long_trace.total_elems();
    results.push(
        b.bench("long contiguous spans (scalar)", || {
            let mut sim = MemSim::new(cfg.clone());
            black_box(sim.run_trace_scalar(&long_trace));
        })
        .with_work(long_elems, long_bursts),
    );
    let m_long_scalar = results.last().unwrap().summary.median;
    results.push(
        b.bench("long contiguous spans (streaming)", || {
            let mut sim = MemSim::new(cfg.clone());
            black_box(sim.run_trace(&long_trace));
        })
        .with_work(long_elems, long_bursts),
    );
    let m_long_streamed = results.last().unwrap().summary.median;

    // ---- tune points/s, cold vs warm trace cache: several mem variants
    // per geometry, the shape the cache exists for
    let mut space = Space::builtin("tiny").unwrap();
    space.mems = vec![
        MemVariant::paper_default(),
        MemVariant::new(
            "burst64",
            MemConfig {
                max_burst_beats: 64,
                ..MemConfig::default()
            },
        ),
        MemVariant::new(
            "outst4",
            MemConfig {
                max_outstanding: 4,
                ..MemConfig::default()
            },
        ),
    ];
    let points = space.enumerate(&reg).unwrap();
    let n_points = points.len() as u64;
    // identity: warm == cold, field for field
    {
        let warm_ev =
            Evaluator::new(&space, reg.clone()).with_trace_cache(Arc::new(TraceCache::new()));
        let cold_ev = Evaluator::new(&space, reg.clone());
        for p in points.points() {
            let w = warm_ev.evaluate(p).unwrap();
            let c = cold_ev.evaluate(p).unwrap();
            assert_eq!(
                w.to_json().to_string_compact(),
                c.to_json().to_string_compact(),
                "{}",
                p.fingerprint()
            );
        }
    }
    results.push(
        b.bench("tune eval (cold: plan walk per point)", || {
            let ev = Evaluator::new(&space, reg.clone());
            for p in points.points() {
                black_box(ev.evaluate(p).unwrap());
            }
        })
        .with_work(n_points, n_points),
    );
    let m_cold = results.last().unwrap().summary.median;
    let warm_cache = Arc::new(TraceCache::new());
    let warm_ev = Evaluator::new(&space, reg.clone()).with_trace_cache(warm_cache.clone());
    for p in points.points() {
        warm_ev.evaluate(p).unwrap(); // prewarm every geometry
    }
    results.push(
        b.bench("tune eval (warm trace cache)", || {
            for p in points.points() {
                black_box(warm_ev.evaluate(p).unwrap());
            }
        })
        .with_work(n_points, n_points),
    );
    let m_warm = results.last().unwrap().summary.median;
    assert!(warm_cache.hits() > 0);

    let replay_speedup = m_trace_scalar / m_trace_streamed;
    let long_speedup = m_long_scalar / m_long_streamed;
    let tune_speedup = m_cold / m_warm;

    println!("\nreplay-throughput benchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
    println!(
        "\nspeedups: streaming replay {replay_speedup:.2}x, long-span kernel \
         {long_speedup:.2}x, warm-cache tune {tune_speedup:.2}x"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("replay_throughput")),
        ("smoke", Json::Bool(smoke)),
        (
            "workload",
            Json::obj(vec![
                ("benchmark", Json::str("jacobi2d5p")),
                ("tile", Json::arr(tile.iter().map(|&x| Json::num(x as f64)))),
                ("tiles_per_dim", Json::num(tiles_per_dim as f64)),
                ("layouts", Json::num(sessions.len() as f64)),
                ("axi_bursts", Json::num(total_bursts as f64)),
                ("tune_points", Json::num(n_points as f64)),
            ]),
        ),
        (
            "speedups",
            Json::obj(vec![
                ("trace_streaming_vs_scalar", Json::num(replay_speedup)),
                ("long_span_streaming_vs_scalar", Json::num(long_speedup)),
                ("tune_warm_vs_cold", Json::num(tune_speedup)),
            ]),
        ),
        ("identity_asserted", Json::Bool(true)),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
