//! `cfa serve` request throughput: tune requests through the daemon cold
//! vs warm shared caches, and two concurrent same-geometry tenants on the
//! shared single-flight caches vs two private explorers.
//!
//! Run: `cargo bench --bench serve_throughput [-- --smoke] [-- --out PATH]`
//!
//! Every run first asserts the daemon's identities — a tune journal
//! written through `serve` is byte-identical to a standalone explorer's,
//! and two racing same-geometry tenants cost exactly one compile per
//! distinct geometry — then records machine-readable results to
//! `BENCH_serve.json` at the repo root (override with `--out`). `--smoke`
//! runs check the rig, not the numbers: without an explicit `--out` they
//! write `BENCH_serve.smoke.json`, so a CI smoke pass can never clobber
//! real recorded results.

use std::io::{Cursor, Write};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use cfa::dse::{Exhaustive, Explorer, Space};
use cfa::layout::registry;
use cfa::serve::Server;
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("points_per_s", Json::num(e)));
    }
    Json::obj(fields)
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(name);
    std::fs::remove_file(&p).ok();
    p
}

fn sink() -> (Arc<Mutex<Vec<u8>>>, Arc<Mutex<dyn Write + Send>>) {
    let buf: Arc<Mutex<Vec<u8>>> = Arc::new(Mutex::new(Vec::new()));
    (buf.clone(), buf as Arc<Mutex<dyn Write + Send>>)
}

fn tune_script(id: &str, out: Option<&PathBuf>) -> String {
    match out {
        Some(p) => format!(
            "{{\"cmd\":\"tune\",\"id\":\"{id}\",\"space\":\"tiny\",\"out\":\"{}\"}}\n",
            p.display()
        ),
        None => format!("{{\"cmd\":\"tune\",\"id\":\"{id}\",\"space\":\"tiny\"}}\n"),
    }
}

/// Spin until the terminal reply for `id` shows up in the sink (the
/// connection returns at EOF while the job still runs on a worker).
fn wait_terminal(buf: &Arc<Mutex<Vec<u8>>>, id: &str) {
    let done = format!("\"event\":\"done\",\"id\":\"{id}\"");
    let err = format!("\"event\":\"error\",\"id\":\"{id}\"");
    loop {
        {
            let bytes = buf.lock().unwrap();
            let text = String::from_utf8_lossy(&bytes);
            if text.contains(&done) {
                return;
            }
            assert!(!text.contains(&err), "request {id} errored: {text}");
        }
        std::thread::yield_now();
    }
}

/// One tune request through an already-running daemon, waited to
/// completion.
fn daemon_tune(server: &Server, id: &str, out: Option<&PathBuf>) {
    let (buf, writer) = sink();
    server.serve_connection(Cursor::new(tune_script(id, out)), writer, false);
    wait_terminal(&buf, id);
}

/// Two tenants on their own connections, racing through one daemon.
fn daemon_tune_pair(server: &Arc<Server>) {
    let handles: Vec<_> = ["p0", "p1"]
        .into_iter()
        .map(|id| {
            let server = server.clone();
            std::thread::spawn(move || {
                let (buf, writer) = sink();
                server.serve_connection(Cursor::new(tune_script(id, None)), writer, false);
                wait_terminal(&buf, id);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut results: Vec<Measurement> = Vec::new();
    let reg = registry::global();
    let n_points = Space::builtin("tiny")
        .unwrap()
        .enumerate(&reg)
        .unwrap()
        .len() as u64;

    // ---- identity gate 1: a daemon tune journal is byte-identical to a
    // standalone explorer's
    let ref_path = tmp("cfa_bench_serve_ref.jsonl");
    Explorer::new(Space::builtin("tiny").unwrap(), Box::new(Exhaustive::new()))
        .registry(reg.clone())
        .journal(&ref_path)
        .explore()
        .unwrap();
    let daemon_path = tmp("cfa_bench_serve_daemon.jsonl");
    {
        let server = Server::new(2, 8);
        daemon_tune(&server, "gate", Some(&daemon_path));
        server.shutdown_and_join();
    }
    assert_eq!(
        std::fs::read(&daemon_path).unwrap(),
        std::fs::read(&ref_path).unwrap(),
        "daemon journal bytes == cfa tune journal bytes"
    );

    // ---- identity gate 2: two racing same-geometry tenants cost exactly
    // one compile per distinct geometry (single-flight batching)
    {
        let server = Arc::new(Server::new(4, 16));
        daemon_tune_pair(&server);
        let s = server.state().traces().stats();
        assert_eq!(s.misses, n_points, "misses == distinct geometries");
        assert_eq!(s.hits + s.misses, 2 * n_points, "every request accounted");
        server.shutdown_and_join();
    }
    println!(
        "identity: daemon tune bytes == standalone tune; \
         2-tenant race compiles each of {n_points} geometries once"
    );

    // ---- baseline: one standalone explorer, no daemon in the way
    results.push(
        b.bench("tune standalone (private explorer)", || {
            let out = Explorer::new(Space::builtin("tiny").unwrap(), Box::new(Exhaustive::new()))
                .registry(reg.clone())
                .explore()
                .unwrap();
            black_box(out.evaluated);
        })
        .with_work(n_points, n_points),
    );

    // ---- request through a cold daemon: fresh caches every iteration
    results.push(
        b.bench("tune via daemon (cold shared caches)", || {
            let server = Server::new(2, 8);
            daemon_tune(&server, "cold", None);
            server.shutdown_and_join();
        })
        .with_work(n_points, n_points),
    );
    let m_cold = results.last().unwrap().summary.median;

    // ---- request through a warm daemon: the steady state a long-lived
    // service actually runs in
    let warm = Server::new(2, 8);
    daemon_tune(&warm, "prewarm", None);
    results.push(
        b.bench("tune via daemon (warm shared caches)", || {
            daemon_tune(&warm, "warm", None);
        })
        .with_work(n_points, n_points),
    );
    let m_warm = results.last().unwrap().summary.median;
    assert!(warm.state().traces().stats().hits > 0);
    warm.shutdown_and_join();

    // ---- two concurrent same-geometry tenants: private explorers
    // (every tenant compiles everything) vs one daemon (single-flight)
    results.push(
        b.bench("2 tenants, private explorers", || {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let reg = reg.clone();
                    std::thread::spawn(move || {
                        Explorer::new(
                            Space::builtin("tiny").unwrap(),
                            Box::new(Exhaustive::new()),
                        )
                        .registry(reg)
                        .explore()
                        .unwrap()
                        .evaluated
                    })
                })
                .collect();
            for h in handles {
                black_box(h.join().unwrap());
            }
        })
        .with_work(2 * n_points, 2 * n_points),
    );
    let m_private = results.last().unwrap().summary.median;
    results.push(
        b.bench("2 tenants via daemon (shared single-flight)", || {
            let server = Arc::new(Server::new(4, 16));
            daemon_tune_pair(&server);
            server.shutdown_and_join();
        })
        .with_work(2 * n_points, 2 * n_points),
    );
    let m_shared = results.last().unwrap().summary.median;

    let warm_speedup = m_cold / m_warm;
    let shared_speedup = m_private / m_shared;

    println!("\nserve-throughput benchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
    println!(
        "\nspeedups: warm daemon {warm_speedup:.2}x vs cold, shared caches \
         {shared_speedup:.2}x vs private for 2 same-geometry tenants"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("smoke", Json::Bool(smoke)),
        (
            "workload",
            Json::obj(vec![
                ("space", Json::str("tiny")),
                ("tune_points", Json::num(n_points as f64)),
                ("tenants", Json::num(2.0)),
            ]),
        ),
        (
            "speedups",
            Json::obj(vec![
                ("tune_warm_vs_cold", Json::num(warm_speedup)),
                ("shared_vs_private_two_tenants", Json::num(shared_speedup)),
            ]),
        ),
        ("identity_asserted", Json::Bool(true)),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
