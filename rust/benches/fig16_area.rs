//! Fig 16 regeneration: slice and DSP occupancy of CFA vs the aggregated
//! baselines, per benchmark (min–max spans, % of xc7z045 resources).
//!
//! Run: `cargo bench --bench fig16_area [-- --quick]`

use cfa::area::Device;
use cfa::harness::{figures, workloads};
use cfa::util::table::{span_chart, SpanRow};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let wl = workloads::table1(quick);
    let pts = figures::area_sweep(&wl, 8, 3);
    std::fs::create_dir_all("bench_results").ok();
    cfa::util::fsx::write_atomic("bench_results/fig16.csv", figures::area_csv(&pts)).ok();

    for (title, metric) in [
        (
            "Fig 16a — logic slice occupancy (% of xc7z045)",
            Box::new(|e: &cfa::area::AreaEstimate, d: &Device| e.slice_pct(d))
                as Box<dyn Fn(&cfa::area::AreaEstimate, &Device) -> f64>,
        ),
        (
            "Fig 16b — DSP occupancy (% of xc7z045)",
            Box::new(|e: &cfa::area::AreaEstimate, d: &Device| e.dsp_pct(d)),
        ),
    ] {
        let agg = figures::fig16_aggregate(&pts, &metric);
        let mut rows = Vec::new();
        for (b, cmin, cmax, bmin, bmax) in &agg {
            rows.push(SpanRow {
                label: format!("{b} cfa"),
                min: *cmin,
                max: *cmax,
                marker: None,
            });
            rows.push(SpanRow {
                label: format!("{b} base"),
                min: *bmin,
                max: *bmax,
                marker: None,
            });
        }
        println!("{}", span_chart(title, &rows, 10.0, 50, "%"));
    }
    println!("wrote bench_results/fig16.csv ({} points)", pts.len());
}
