//! Multi-channel scaling: simulated effective MB/s and host replay
//! throughput (bursts/s) vs channel count, for every striping policy.
//!
//! Run: `cargo bench --bench channel_scaling [-- --smoke] [-- --out PATH]`
//!
//! Every run first asserts the multi-channel identities **bit-identical**
//! (channels=1 ≡ the single-port engine under each policy; pre-split
//! parallel replay ≡ entry-wise submit, full per-channel snapshots), then
//! sweeps channels × striping over one compiled session trace and records
//! machine-readable results to `BENCH_channels.json` at the repo root
//! (override with `--out`). `--smoke` runs check the rig, not the numbers:
//! without an explicit `--out` they write `BENCH_channels.smoke.json`, so
//! a CI smoke pass can never clobber real recorded results.

use cfa::experiment::{ExperimentSpec, ScheduleKind};
use cfa::memsim::{MemConfig, MemSim, MultiPortSim, Striping, Txn};
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("elems_per_s", Json::num(e)));
    }
    if let Some(r) = m.runs_per_sec() {
        fields.push(("bursts_per_s", Json::num(r)));
    }
    Json::obj(fields)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_channels.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_channels.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let cfg = MemConfig::default();

    // one compiled trace, shared by every (channels, striping) variant —
    // exactly what the tune evaluator exploits (routing happens at replay)
    let tile = vec![32i64, 32, 32];
    let tiles_per_dim = if smoke { 3 } else { 4 };
    let session = ExperimentSpec::builder()
        .named("jacobi2d5p", tile.clone(), tiles_per_dim)
        .schedule(ScheduleKind::Flat)
        .mem(cfg.clone())
        .compile()
        .expect("compile session");
    let trace = session.compile_trace();
    let txns: Vec<Txn> = trace.txns();
    let elems = trace.total_elems();
    let useful = trace.useful_elems;
    let stripings = [
        Striping::Address { stripe_bytes: 4096 },
        Striping::Facet,
        Striping::Tile,
    ];

    // ---- identity gate, full replay state compared
    let serial_snapshot = {
        let mut s = MemSim::new(cfg.clone());
        s.run_trace(&trace);
        s.snapshot()
    };
    for striping in &stripings {
        // channels=1 is the plain single-port engine, whatever the policy
        let map = striping
            .resolve(session.allocation(), cfg.elem_bytes, 1)
            .expect("resolve striping");
        let mut one = MultiPortSim::new(cfg.clone(), 1, map);
        one.run_trace_parallel(&trace, 2);
        assert_eq!(
            one.channel_snapshots()[0],
            serial_snapshot,
            "channels=1 diverged from MemSim under {striping}"
        );
        // pre-split parallel replay == entry-wise submit, per channel
        let map = striping
            .resolve(session.allocation(), cfg.elem_bytes, 4)
            .expect("resolve striping");
        let mut by_txn = MultiPortSim::new(cfg.clone(), 4, map.clone());
        for t in &txns {
            by_txn.submit(t);
        }
        let mut pre_split = MultiPortSim::new(cfg.clone(), 4, map);
        pre_split.run_trace_parallel(&trace, 4);
        assert_eq!(
            pre_split.channel_snapshots(),
            by_txn.channel_snapshots(),
            "pre-split replay diverged from entry-wise submit under {striping}"
        );
    }
    println!("identity: multi-channel replay == single-port / entry-wise reference\n");

    // ---- the sweep: simulated bandwidth and host replay throughput
    let channel_counts = [1usize, 2, 4, 8];
    let mut results: Vec<Measurement> = Vec::new();
    let mut scaling: Vec<Json> = Vec::new();
    println!(
        "{:<10} {:>9} {:>14} {:>12} {:>10}",
        "striping", "channels", "eff MB/s", "roofline", "imbalance"
    );
    for striping in &stripings {
        for &channels in &channel_counts {
            let map = striping
                .resolve(session.allocation(), cfg.elem_bytes, channels)
                .expect("resolve striping");
            let mut mp = MultiPortSim::new(cfg.clone(), channels, map.clone());
            mp.run_trace_parallel(&trace, channels);
            let bw = mp.bandwidth(useful);
            let eff_mb_s = bw.useful_bytes as f64 / 1e6 / cfg.secs(bw.cycles.max(1));
            let imbalance = mp.imbalance();
            let bursts = bw.bursts;
            let roofline = cfg.peak_mb_s() * channels as f64;
            println!(
                "{:<10} {:>9} {:>14.1} {:>12.1} {:>10.3}",
                striping.label(),
                channels,
                eff_mb_s,
                roofline,
                imbalance
            );
            scaling.push(Json::obj(vec![
                ("striping", Json::str(striping.label())),
                ("channels", Json::num(channels as f64)),
                ("eff_mb_s", Json::num(eff_mb_s)),
                ("roofline_mb_s", Json::num(roofline)),
                ("imbalance", Json::num(imbalance)),
                ("axi_bursts", Json::num(bursts as f64)),
                ("makespan_cycles", Json::num(bw.cycles as f64)),
            ]));
            results.push(
                b.bench(
                    &format!("replay {} x{}", striping.label(), channels),
                    || {
                        let mut sim = MultiPortSim::new(cfg.clone(), channels, map.clone());
                        black_box(sim.run_trace_parallel(&trace, channels));
                    },
                )
                .with_work(elems, bursts),
            );
        }
    }

    println!("\nhost replay throughput:");
    for m in &results {
        println!("  {}", m.line());
    }

    let json = Json::obj(vec![
        ("bench", Json::str("channel_scaling")),
        ("smoke", Json::Bool(smoke)),
        (
            "workload",
            Json::obj(vec![
                ("benchmark", Json::str("jacobi2d5p")),
                ("tile", Json::arr(tile.iter().map(|&x| Json::num(x as f64)))),
                ("tiles_per_dim", Json::num(tiles_per_dim as f64)),
                ("trace_elems", Json::num(elems as f64)),
                ("peak_mb_s_per_channel", Json::num(cfg.peak_mb_s())),
            ]),
        ),
        ("identity_asserted", Json::Bool(true)),
        ("scaling", Json::arr(scaling.into_iter())),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
