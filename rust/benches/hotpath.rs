//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 kernels that dominate figure sweeps and coordinated runs, plus the
//! burst-grained fast path (run cursors + plan memoization) measured against
//! a faithful reimplementation of the pre-fast-path pointwise code.
//!
//! Run: `cargo bench --bench hotpath [-- --smoke] [-- --out PATH]`
//!
//! Every run asserts the fast path **bit-identical** to the reference
//! (plans, memory-simulator timing counters, marshalled buffers) before
//! timing anything, and writes machine-readable results to
//! `BENCH_hotpath.json` at the repo root (override with `--out`), so the
//! perf trajectory is recorded run over run. `--smoke` runs exist to
//! check the rig, not to measure: without an explicit `--out` they write
//! to `BENCH_hotpath.smoke.json` instead, so a CI smoke pass can never
//! clobber real recorded results with throwaway numbers.

use cfa::coordinator::HostMemory;
use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind, Session};
use cfa::harness::workloads;
use cfa::layout::registry::{self, names};
use cfa::layout::{runs_of_box, Allocation, PlanCache, TilePlan};
use cfa::memsim::{Dir, MemConfig, MemSim, Timing, Txn};
use cfa::poly::deps::DepPattern;
use cfa::poly::flow::flow_in;
use cfa::poly::rect::Rect;
use cfa::poly::tiling::Tiling;
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

/// Plan every tile with one full derivation per tile — the sweeps' pre-PR
/// planning path (no memoization).
fn plan_fresh(alloc: &dyn Allocation, tiles: &[Vec<i64>]) -> Vec<TilePlan> {
    tiles.iter().map(|tc| alloc.plan(tc)).collect()
}

/// Plan every tile through a [`PlanCache`]: interior tiles rebase one
/// canonical plan.
fn plan_memoized(alloc: &dyn Allocation, tiles: &[Vec<i64>]) -> Vec<TilePlan> {
    let cache = PlanCache::new(alloc);
    tiles.iter().map(|tc| cache.plan(tc)).collect()
}

/// The pre-PR marshalling loop, verbatim semantics: gather one `addr_of`
/// per point through an allocating point iterator, write through a fresh
/// `write_locs` Vec per point.
fn marshal_pointwise(
    alloc: &dyn Allocation,
    plans: &[TilePlan],
    host: &HostMemory,
    out: &mut HostMemory,
) {
    for plan in plans {
        let mut acc = 0f32;
        let mut n = 0u64;
        for pc in &plan.read_pieces {
            for p in pc.iter_box.points() {
                acc += host.read(alloc.addr_of(pc.array, &p));
                n += 1;
            }
        }
        let bias = if n == 0 { 0.0 } else { acc / n as f32 };
        for pc in &plan.write_pieces {
            for p in pc.iter_box.points() {
                for (_, addr) in alloc.write_locs(&p) {
                    out.write(addr, bias + 0.25);
                }
            }
        }
    }
}

/// The fast marshalling loop: run cursor for the gather (contiguous host
/// slices, same fold order), streamed write locations, reusable point
/// buffer — zero allocation per point.
fn marshal_runs(
    alloc: &dyn Allocation,
    plans: &[TilePlan],
    host: &HostMemory,
    out: &mut HostMemory,
) {
    let mem = host.as_slice();
    for plan in plans {
        let mut acc = 0f32;
        let mut n = 0u64;
        for pc in &plan.read_pieces {
            alloc.for_each_run(pc.array, &pc.iter_box, &mut |addr, len| {
                for &v in &mem[addr as usize..(addr + len) as usize] {
                    acc += v;
                }
                n += len;
            });
        }
        let bias = if n == 0 { 0.0 } else { acc / n as f32 };
        for pc in &plan.write_pieces {
            pc.iter_box.for_each_point(&mut |p| {
                alloc.for_each_write_loc(p, &mut |_, addr| out.write(addr, bias + 0.25));
            });
        }
    }
}

/// Replay plans through a fresh simulator, lexicographic tile order (the
/// Fig-15 memory-bound rig's submit order).
fn replay(cfg: &MemConfig, plans: &[TilePlan]) -> (u64, Timing) {
    let mut sim = MemSim::new(cfg.clone());
    for plan in plans {
        for r in &plan.read_runs {
            sim.submit(&Txn {
                dir: Dir::Read,
                addr: r.addr,
                len: r.len,
            });
        }
        for r in &plan.write_runs {
            sim.submit(&Txn {
                dir: Dir::Write,
                addr: r.addr,
                len: r.len,
            });
        }
    }
    (sim.now(), sim.timing().clone())
}

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("elems_per_s", Json::num(e)));
    }
    if let Some(r) = m.runs_per_sec() {
        fields.push(("runs_per_s", Json::num(r)));
    }
    Json::obj(fields)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            // smoke numbers must never overwrite real recorded results
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut results: Vec<Measurement> = Vec::new();

    // ---- micro benches (unchanged targets, tracked run over run)
    let w = workloads::by_name("jacobi2d9p").unwrap();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![384, 384, 384], vec![128, 128, 128]);
    let mid = vec![1, 1, 1];

    results.push(b.bench("flow_in(128^3 tile)", || {
        black_box(flow_in(&tiling, &deps, &mid));
    }));

    let cfa128 = cfa::layout::cfa::Cfa::new(tiling.clone(), deps.clone()).unwrap();
    results.push(b.bench("cfa.plan(128^3 interior tile)", || {
        black_box(cfa128.plan(&mid));
    }));

    let orig128 = cfa::layout::original::OriginalLayout::new(tiling.clone(), deps.clone());
    results.push(b.bench("original.plan(128^3 interior tile)", || {
        black_box(orig128.plan(&mid));
    }));

    let bx = Rect::new(vec![1, 0, 0], vec![2, 126, 128]);
    results.push(b.bench("runs_of_box(partial 3d box)", || {
        black_box(runs_of_box(&bx, &[3, 128, 128], 0));
    }));

    let cfg = MemConfig::default();
    let txns: Vec<Txn> = (0..1024)
        .map(|i| Txn {
            dir: if i % 3 == 0 { Dir::Write } else { Dir::Read },
            addr: (i * 517) % 100_000,
            len: 64,
        })
        .collect();
    results.push(b.bench("memsim 1024 txns", || {
        let mut sim = MemSim::new(cfg.clone());
        black_box(sim.run(&txns));
    }));

    // ---- the Fig-15 sweep planning + marshalling path: pre-PR pointwise
    // reference vs the burst-grained fast path, identity asserted first.
    // allocations are owned by experiment sessions (the production front
    // door), proving the session API adds no overhead on the hot path.
    let sweep_w = workloads::by_name("jacobi2d5p").unwrap();
    let tile = vec![32i64, 32, 32];
    let tiles_per_dim = 6i64;
    let sweep_tiling = Tiling::new(sweep_w.space_for(&tile, tiles_per_dim), tile.clone());
    let tiles: Vec<Vec<i64>> = sweep_tiling.tiles().collect();
    let reg = registry::global();
    let sessions: Vec<Session> = reg
        .names()
        .iter()
        .map(|&name| {
            ExperimentSpec::builder()
                .custom(
                    sweep_w.name,
                    sweep_tiling.space.clone(),
                    tile.clone(),
                    sweep_w.deps.clone(),
                )
                .layout(name)
                .schedule(ScheduleKind::Flat)
                .mem(cfg.clone())
                .registry(reg.clone())
                .compile()
                .expect("compile session")
        })
        .collect();

    // identity: memoized plans == fresh plans, and identical replay timing;
    // also total up the planning work across all four allocations for the
    // plan benches' throughput lines
    let mut planned_elems = 0u64;
    let mut planned_runs = 0u64;
    for session in &sessions {
        let alloc = session.allocation();
        let fresh = plan_fresh(alloc, &tiles);
        let memo = plan_memoized(alloc, &tiles);
        assert_eq!(fresh, memo, "{}: memoized plans differ", alloc.name());
        planned_elems += fresh
            .iter()
            .map(|p| p.read_raw() + p.write_raw())
            .sum::<u64>();
        planned_runs += fresh.iter().map(|p| p.transactions() as u64).sum::<u64>();
        let (c_f, t_f) = replay(&cfg, &fresh);
        let (c_m, t_m) = replay(&cfg, &memo);
        assert_eq!(c_f, c_m, "{}: cycles differ", alloc.name());
        assert_eq!(t_f, t_m, "{}: Timing counters differ", alloc.name());
        // the production sweep path (Session in Mode::Sweep: flat replay
        // through the batch coordinator) reproduces the fresh replay exactly
        let rep = session.run(Mode::Sweep).expect("session sweep");
        assert_eq!(rep.makespan_cycles, c_f, "{}: session cycles", alloc.name());
        assert_eq!(
            rep.timing.as_ref(),
            Some(&t_f),
            "{}: session Timing",
            alloc.name()
        );
    }

    // identity: pointwise and run-cursor marshalling produce bit-identical
    // buffers (CFA, the allocation with replicated writes)
    let cfa_sweep = sessions
        .iter()
        .find(|s| s.layout() == names::CFA)
        .expect("cfa session")
        .allocation();
    let cfa_plans = plan_fresh(cfa_sweep, &tiles);
    let mut host = HostMemory::new(cfa_sweep.footprint());
    for i in 0..host.len() as u64 {
        host.write(i, (i % 251) as f32 * 0.5 + 1.0);
    }
    let (mut out_pw, mut out_rc) = (
        HostMemory::new(cfa_sweep.footprint()),
        HostMemory::new(cfa_sweep.footprint()),
    );
    marshal_pointwise(cfa_sweep, &cfa_plans, &host, &mut out_pw);
    marshal_runs(cfa_sweep, &cfa_plans, &host, &mut out_rc);
    assert_eq!(out_pw.len(), out_rc.len());
    for (i, (x, y)) in out_pw.as_slice().iter().zip(out_rc.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "marshal buffers differ at {i}");
    }
    println!(
        "identity: plans, Timing counters and marshalled buffers bit-identical \
         ({} tiles, 4 allocations)",
        tiles.len()
    );

    // work counts for throughput lines; the marshal path's run count is the
    // number of runs the cursor actually emits over the read pieces (not
    // the timing path's merged transaction count)
    let marshal_elems: u64 = cfa_plans
        .iter()
        .map(|p| p.read_useful + p.write_useful)
        .sum();
    let mut marshal_runs_emitted = 0u64;
    for plan in &cfa_plans {
        for pc in &plan.read_pieces {
            cfa_sweep.for_each_run(pc.array, &pc.iter_box, &mut |_, _| {
                marshal_runs_emitted += 1;
            });
        }
    }

    let m_plan_fresh = b
        .bench("fig15 sweep plan x4 allocs (fresh)", || {
            for session in &sessions {
                black_box(plan_fresh(session.allocation(), &tiles));
            }
        })
        .with_work(planned_elems, planned_runs);
    let m_plan_memo = b
        .bench("fig15 sweep plan x4 allocs (memoized)", || {
            for session in &sessions {
                black_box(plan_memoized(session.allocation(), &tiles));
            }
        })
        .with_work(planned_elems, planned_runs);
    let m_marshal_pw = b
        .bench("fig15 sweep marshal cfa (pointwise)", || {
            marshal_pointwise(cfa_sweep, &cfa_plans, &host, &mut out_pw);
        })
        .with_work(marshal_elems, marshal_runs_emitted);
    let m_marshal_rc = b
        .bench("fig15 sweep marshal cfa (run cursor)", || {
            marshal_runs(cfa_sweep, &cfa_plans, &host, &mut out_rc);
        })
        .with_work(marshal_elems, marshal_runs_emitted);

    let plan_speedup = m_plan_fresh.summary.median / m_plan_memo.summary.median;
    let marshal_speedup = m_marshal_pw.summary.median / m_marshal_rc.summary.median;
    let combined_speedup = (m_plan_fresh.summary.median + m_marshal_pw.summary.median)
        / (m_plan_memo.summary.median + m_marshal_rc.summary.median);

    results.push(m_plan_fresh);
    results.push(m_plan_memo);
    results.push(m_marshal_pw);
    results.push(m_marshal_rc);

    println!("\nhotpath microbenchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
    println!(
        "\nfig15 sweep path speedups: plan {plan_speedup:.2}x, marshal \
         {marshal_speedup:.2}x, combined {combined_speedup:.2}x"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("smoke", Json::Bool(smoke)),
        (
            "sweep",
            Json::obj(vec![
                ("benchmark", Json::str(sweep_w.name)),
                (
                    "tile",
                    Json::arr(tile.iter().map(|&x| Json::num(x as f64))),
                ),
                ("tiles_per_dim", Json::num(tiles_per_dim as f64)),
                ("tiles", Json::num(tiles.len() as f64)),
            ]),
        ),
        (
            "speedups",
            Json::obj(vec![
                ("fig15_plan", Json::num(plan_speedup)),
                ("fig15_marshal", Json::num(marshal_speedup)),
                ("fig15_combined", Json::num(combined_speedup)),
            ]),
        ),
        ("identity_asserted", Json::Bool(true)),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
