//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 kernels that dominate figure sweeps and coordinated runs.
//!
//! Run: `cargo bench --bench hotpath`

use cfa::harness::workloads;
use cfa::layout::{runs_of_box, Allocation};
use cfa::memsim::{Dir, MemConfig, MemSim, Txn};
use cfa::poly::deps::DepPattern;
use cfa::poly::flow::flow_in;
use cfa::poly::rect::Rect;
use cfa::poly::tiling::Tiling;
use cfa::util::stats::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    let w = workloads::by_name("jacobi2d9p").unwrap();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tiling = Tiling::new(vec![384, 384, 384], vec![128, 128, 128]);
    let mid = vec![1, 1, 1];

    let mut results = Vec::new();

    results.push(b.bench("flow_in(128^3 tile)", || {
        black_box(flow_in(&tiling, &deps, &mid));
    }));

    let cfa = cfa::layout::cfa::Cfa::new(tiling.clone(), deps.clone()).unwrap();
    results.push(b.bench("cfa.plan(128^3 interior tile)", || {
        black_box(cfa.plan(&mid));
    }));

    let orig = cfa::layout::original::OriginalLayout::new(tiling.clone(), deps.clone());
    results.push(b.bench("original.plan(128^3 interior tile)", || {
        black_box(orig.plan(&mid));
    }));

    let bx = Rect::new(vec![1, 0, 0], vec![2, 126, 128]);
    results.push(b.bench("runs_of_box(partial 3d box)", || {
        black_box(runs_of_box(&bx, &[3, 128, 128], 0));
    }));

    let cfg = MemConfig::default();
    let txns: Vec<Txn> = (0..1024)
        .map(|i| Txn {
            dir: if i % 3 == 0 { Dir::Write } else { Dir::Read },
            addr: (i * 517) % 100_000,
            len: 64,
        })
        .collect();
    results.push(b.bench("memsim 1024 txns", || {
        let mut sim = MemSim::new(cfg.clone());
        black_box(sim.run(&txns));
    }));

    let plan = cfa.plan(&mid);
    let mut sim = MemSim::new(cfg.clone());
    results.push(b.bench("tile_mem_cycles(cfa plan)", || {
        black_box(cfa::accel::tile_mem_cycles(
            &mut sim,
            &plan.read_runs,
            &plan.write_runs,
        ));
    }));

    println!("\nhotpath microbenchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
}
