//! Fig 17 regeneration: BRAM occupancy per allocation and benchmark (% of
//! the xc7z045's 545 BRAM36). The claim to reproduce: CFA ≈ original
//! (CFA does not change the on-chip allocation); bbox and data tiling pay
//! for holding their redundant transfers on chip.
//!
//! Run: `cargo bench --bench fig17_bram [-- --quick]`

use cfa::area::Device;
use cfa::harness::{figures, workloads};
use cfa::util::table::{span_chart, SpanRow};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let wl = workloads::table1(quick);
    let pts = figures::area_sweep(&wl, 8, 3);
    std::fs::create_dir_all("bench_results").ok();
    cfa::util::fsx::write_atomic("bench_results/fig17.csv", figures::area_csv(&pts)).ok();
    let dev = Device::default();
    let reg = cfa::layout::registry::global();
    for w in &wl {
        let mut rows = Vec::new();
        for alloc in reg.names() {
            let vals: Vec<f64> = pts
                .iter()
                .filter(|p| p.benchmark == w.name && p.alloc == alloc)
                .map(|p| p.est.bram_pct(&dev))
                .collect();
            rows.push(SpanRow {
                label: alloc.to_string(),
                min: vals.iter().cloned().fold(f64::INFINITY, f64::min),
                max: vals.iter().cloned().fold(0.0, f64::max),
                marker: None,
            });
        }
        println!(
            "{}",
            span_chart(
                &format!("Fig 17 — BRAM occupancy, {}", w.name),
                &rows,
                100.0,
                50,
                "%"
            )
        );
    }
    println!("wrote bench_results/fig17.csv");
}
