//! Observability-overhead microbenchmarks: what does instrumentation
//! cost when it is off (the permanent price every run pays) and when it
//! is on (the price of `--profile` / `--timeline`)?
//!
//! Run: `cargo bench --bench obs_overhead [-- --smoke] [-- --out PATH]`
//!
//! Three measurements:
//! * disabled `span()` throughput — the fast path the hot loops keep
//!   forever (one relaxed load; `tests/obs_alloc.rs` pins it to zero
//!   allocations, this bench records its rate);
//! * enabled `span()` throughput under an active capture (clock read +
//!   sink push through the global mutex);
//! * memsim trace replay with observability fully off vs fully on
//!   (active capture + attached timeline sampler). The **gate**: the
//!   obs-on replay may cost at most 2% more than obs-off (asserted in
//!   full runs; `--smoke` runs only record).
//!
//! Before timing anything the bench asserts sampling is passive: the
//! sampled replay's final `Timing` is bit-identical to the unsampled
//! one and the timeline epochs sum to it exactly.
//!
//! Results land in `BENCH_obs.json` at the repo root (override with
//! `--out`); `--smoke` writes `BENCH_obs.smoke.json` so CI can never
//! clobber recorded numbers with throwaway ones.

use cfa::memsim::{Dir, MemConfig, MemSim, TxnTrace};
use cfa::obs::{begin_capture, Timeline};
use cfa::util::json::Json;
use cfa::util::stats::{black_box, Bencher, Measurement};

/// Spans opened per bench iteration (throughput divisor).
const SPANS_PER_ITER: u64 = 1024;

/// A replay workload big enough that per-call span cost amortizes away
/// and per-txn sampler cost is measured against real burst work: long
/// same-direction contiguous spans (streaming kernel) interleaved with
/// scattered short writes (scalar fallback), element-granular like the
/// compiled session traces.
fn replay_trace() -> TxnTrace {
    let mut t = TxnTrace::new();
    let mut cursor = 0u64;
    for i in 0..4096u64 {
        if i % 5 == 4 {
            t.push(Dir::Write, (i * 977) % 100_000, 16);
        } else {
            t.push(Dir::Read, cursor, 64);
            cursor += 64;
        }
    }
    t
}

fn measurement_json(m: &Measurement) -> Json {
    let mut fields = vec![
        ("name", Json::str(m.name.clone())),
        ("median_s", Json::num(m.summary.median)),
        ("p05_s", Json::num(m.summary.p05)),
        ("p95_s", Json::num(m.summary.p95)),
        ("samples", Json::num(m.summary.n as f64)),
    ];
    if let Some(e) = m.elems_per_sec() {
        fields.push(("elems_per_s", Json::num(e)));
    }
    if let Some(r) = m.runs_per_sec() {
        fields.push(("runs_per_s", Json::num(r)));
    }
    Json::obj(fields)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            // smoke numbers must never overwrite real recorded results
            if smoke {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.smoke.json").to_string()
            } else {
                concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json").to_string()
            }
        });
    let b = if smoke { Bencher::quick() } else { Bencher::default() };
    let cfg = MemConfig::default();
    let trace = replay_trace();

    // ---- identity before timing: sampling is passive and epochs sum
    // exactly to the aggregate counters
    assert!(!cfa::obs::enabled(), "no capture may be active at startup");
    let plain_timing = {
        let mut sim = MemSim::new(cfg.clone());
        sim.run_trace(&trace);
        sim.timing().clone()
    };
    {
        let mut sim = MemSim::new(cfg.clone());
        sim.set_sampler(4096);
        sim.run_trace(&trace);
        assert_eq!(
            sim.timing(),
            &plain_timing,
            "attaching a sampler changed the replay"
        );
        let tl = Timeline {
            epoch_cycles: 4096,
            channels: vec![sim.take_sampler().unwrap().into_epochs()],
        };
        assert!(tl.matches(&plain_timing), "epoch sums != aggregate Timing");
    }
    println!(
        "identity: sampled replay Timing bit-identical, epochs sum to aggregate \
         ({} txns)",
        trace.len()
    );

    let mut results: Vec<Measurement> = Vec::new();

    // ---- span throughput, disabled then enabled
    let m_span_off = b
        .bench("span() x1024 (disabled)", || {
            for _ in 0..SPANS_PER_ITER {
                let _s = cfa::obs::span("bench::off");
                black_box(&_s);
            }
        })
        .with_work(SPANS_PER_ITER, 0);
    // the capture opens and closes inside the iteration so the sink is
    // drained every time (the last capture out clears it) — the bench
    // cannot grow the event buffer without bound
    let m_span_on = b
        .bench("span() x1024 (capture active)", || {
            let cap = begin_capture();
            for _ in 0..SPANS_PER_ITER {
                let _s = cfa::obs::span("bench::on");
                black_box(&_s);
            }
            drop(cap);
        })
        .with_work(SPANS_PER_ITER, 0);

    // ---- replay throughput, obs fully off vs fully on
    let m_replay_off = b
        .bench("memsim replay 4096 txns (obs off)", || {
            let mut sim = MemSim::new(cfg.clone());
            black_box(sim.run_trace(&trace));
        })
        .with_work(trace.len() as u64, 0);
    let m_replay_on = b
        .bench("memsim replay 4096 txns (obs on)", || {
            let cap = begin_capture();
            let mut sim = MemSim::new(cfg.clone());
            sim.set_sampler(4096);
            black_box(sim.run_trace(&trace));
            drop(cap);
        })
        .with_work(trace.len() as u64, 0);

    let overhead =
        (m_replay_on.summary.median - m_replay_off.summary.median) / m_replay_off.summary.median;
    let overhead_pct = overhead * 100.0;
    let gate_passed = overhead_pct < 2.0;

    let spans_per_s_off = m_span_off.elems_per_sec();
    let spans_per_s_on = m_span_on.elems_per_sec();

    results.push(m_span_off);
    results.push(m_span_on);
    results.push(m_replay_off);
    results.push(m_replay_on);

    println!("\nobservability microbenchmarks:");
    for m in &results {
        println!("  {}", m.line());
    }
    println!(
        "\nreplay overhead obs on vs off: {overhead_pct:+.3}% (gate: < 2%, {})",
        if gate_passed { "pass" } else { "FAIL" }
    );

    let json = Json::obj(vec![
        ("bench", Json::str("obs")),
        ("smoke", Json::Bool(smoke)),
        (
            "spans",
            Json::obj(vec![
                (
                    "disabled_per_s",
                    spans_per_s_off.map_or(Json::Null, |v| Json::num(v)),
                ),
                (
                    "enabled_per_s",
                    spans_per_s_on.map_or(Json::Null, |v| Json::num(v)),
                ),
            ]),
        ),
        (
            "replay_overhead",
            Json::obj(vec![
                ("txns", Json::num(trace.len() as f64)),
                ("overhead_pct", Json::num(overhead_pct)),
                ("gate_pct", Json::num(2.0)),
                ("gate_passed", Json::Bool(gate_passed)),
            ]),
        ),
        ("identity_asserted", Json::Bool(true)),
        (
            "measurements",
            Json::arr(results.iter().map(measurement_json)),
        ),
    ]);
    // temp-then-rename: a killed bench never leaves a truncated schema seed
    match cfa::util::fsx::write_atomic(&out_path, json.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    // the gate is advisory in smoke runs (quick sampling is too noisy
    // to fail CI on) and binding in full runs
    if !smoke {
        assert!(
            gate_passed,
            "obs-on replay overhead {overhead_pct:.3}% breaches the 2% gate"
        );
    }
}
