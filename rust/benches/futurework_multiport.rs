//! §VII future-work experiment: multi-port (HBM-style) memory.
//!
//! The paper: "to benefit from all their bandwidth, one has to find an
//! adequate repartition of data over each memory port to balance
//! accesses." CFA's facet arrays are contiguous, independent regions —
//! assigning one facet array per port is that repartition. This bench
//! sweeps 1/2/4 ports and compares:
//!
//! * CFA with the facet-per-port map (ByRange),
//! * CFA on a plain address-interleaved controller,
//! * the original layout, interleaved (its only option).
//!
//! Run: `cargo bench --bench futurework_multiport`

use cfa::coordinator::AllocKind;
use cfa::harness::workloads;
use cfa::layout::cfa::Cfa;
use cfa::layout::Allocation;
use cfa::memsim::{cfa_port_map, Dir, MemConfig, MultiPortSim, PortMap, Txn};
use cfa::poly::deps::DepPattern;
use cfa::poly::tiling::Tiling;

fn run_alloc(
    alloc: &dyn Allocation,
    tiling: &Tiling,
    sim: &mut MultiPortSim,
) -> (u64, u64) {
    let mut useful = 0u64;
    for coords in tiling.tiles() {
        let plan = alloc.plan(&coords);
        for r in &plan.read_runs {
            sim.submit(&Txn { dir: Dir::Read, addr: r.addr, len: r.len });
        }
        for r in &plan.write_runs {
            sim.submit(&Txn { dir: Dir::Write, addr: r.addr, len: r.len });
        }
        useful += plan.read_useful + plan.write_useful;
    }
    (sim.now(), useful)
}

fn main() {
    let w = workloads::by_name("jacobi2d9p").unwrap();
    let deps = DepPattern::new(w.deps.clone()).unwrap();
    let tile = vec![32i64, 32, 32];
    let tiling = Tiling::new(w.space_for(&tile, 3), tile);
    let mem = MemConfig::default();
    let cfa = Cfa::new(tiling.clone(), deps.clone()).unwrap();
    let orig = AllocKind::Original.build(&tiling, &deps).unwrap();

    println!("multi-port scaling, jacobi2d9p 32^3 tiles (eff MB/s, imbalance):\n");
    println!(
        "{:<28} {:>9} {:>9} {:>9}",
        "configuration", "1 port", "2 ports", "4 ports"
    );
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, which) in [
        ("cfa facet-per-port", 0usize),
        ("cfa interleaved 4KiB", 1),
        ("original interleaved 4KiB", 2),
    ] {
        let mut vals = Vec::new();
        for ports in [1usize, 2, 4] {
            let map = match which {
                0 => cfa_port_map(&cfa, ports),
                // 4 KiB byte stripes, expressed in element units
                _ => PortMap::Interleaved {
                    stripe_elems: 4096 / mem.elem_bytes.max(1),
                },
            };
            let mut sim = MultiPortSim::new(mem.clone(), ports, map);
            let (cycles, useful) = match which {
                2 => run_alloc(orig.as_ref(), &tiling, &mut sim),
                _ => run_alloc(&cfa, &tiling, &mut sim),
            };
            let eff = useful as f64 * mem.elem_bytes as f64 / 1e6 / mem.secs(cycles.max(1));
            vals.push(eff);
        }
        println!(
            "{:<28} {:>9.1} {:>9.1} {:>9.1}",
            name, vals[0], vals[1], vals[2]
        );
        rows.push((name.to_string(), vals));
    }
    let per_facet_scale = rows[0].1[2] / rows[0].1[0];
    let interleaved_scale = rows[1].1[2] / rows[1].1[0];
    println!(
        "\nscaling 1->4 ports: facet-per-port {per_facet_scale:.2}x, \
         interleaved {interleaved_scale:.2}x (roofline 4x{} MB/s)\n\
         finding: CFA's bursts are long enough that plain address \
         interleaving already balances the channels; an explicit \
         facet repartition only helps when facet count >= port count \
         and per-facet traffic is even — the \"adequate repartition\" \
         the paper anticipates is a scheduling question, not a layout one.",
        mem.peak_mb_s()
    );
}
