//! Serial vs batched-parallel coordinator on the Table-I sweep.
//!
//! Two measurements, both with bit-identity *asserted* (the property tests
//! in `tests/batch_parallel.rs` are the canonical proof; the bench fails
//! loudly too rather than reporting a speedup for wrong results):
//!
//! 1. the full Fig-15 sweep (benchmarks × tile sizes × allocations), fanned
//!    out across sweep points;
//! 2. one large wavefront-scheduled run, fanned out across tiles within
//!    each dependence wave.
//!
//! Run: `cargo bench --bench parallel_coordinator [-- --threads N] [-- --quick]`

use cfa::experiment::{ExperimentSpec, Mode, ScheduleKind, Session};
use cfa::harness::figures::{fig15_sweep, fig15_sweep_parallel};
use cfa::harness::workloads::{self, table1, Workload};
use cfa::layout::registry::names;
use cfa::memsim::MemConfig;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let threads = args
        .windows(2)
        .find(|w| w[0] == "--threads")
        .and_then(|w| w[1].parse().ok())
        .unwrap_or_else(|| cfa::util::par::default_threads().clamp(4, 8));
    let quick = args.iter().any(|a| a == "--quick");
    let mem = MemConfig::default();

    // ---- 1. sweep-level parallelism (what `cfa bench --parallel N` uses)
    let wl = table1(quick);
    let points: usize = wl.iter().map(|w| w.tile_sizes.len() * 4).sum();
    eprintln!("sweep: {points} points (quick={quick}), {threads} threads");
    let t0 = Instant::now();
    let serial = fig15_sweep(&wl, &mem, 3);
    let t_serial = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let parallel = fig15_sweep_parallel(&wl, &mem, 3, threads);
    let t_parallel = t1.elapsed().as_secs_f64();
    assert_eq!(serial.len(), parallel.len(), "sweep dropped points");
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            s.raw_mb_s.to_bits(),
            p.raw_mb_s.to_bits(),
            "{}/{:?}/{}: raw bandwidth differs",
            s.benchmark,
            s.tile,
            s.alloc
        );
        assert_eq!(s.effective_mb_s.to_bits(), p.effective_mb_s.to_bits());
        assert_eq!(s.transactions, p.transactions);
    }
    println!(
        "fig15 sweep        serial {t_serial:7.2}s   {threads} threads {t_parallel:7.2}s   speedup {:.2}x",
        t_serial / t_parallel.max(1e-9)
    );

    // ---- 2. wave-level parallelism inside one big coordinated run,
    // driven through the experiment session API (one session per worker
    // count; the schedule and plan cache are owned by each session)
    let w = workloads::by_name("jacobi2d9p").unwrap();
    let (edge, tiles_per_dim) = if quick { (16, 4) } else { (32, 6) };
    let tile = vec![edge, edge, edge];
    let wave_session = |w: &Workload, threads: usize| -> Session {
        ExperimentSpec::builder()
            .custom(
                w.name,
                w.space_for(&tile, tiles_per_dim),
                tile.clone(),
                w.deps.clone(),
            )
            .layout(names::CFA)
            .schedule(ScheduleKind::Wavefront)
            .threads(threads)
            .mem(mem.clone())
            .compile()
            .expect("compile session")
    };
    let session_serial = wave_session(&w, 1);
    let session_parallel = wave_session(&w, threads);
    eprintln!(
        "wavefront: {} tiles in {} waves (max width {})",
        session_serial.schedule().num_tiles(),
        session_serial.schedule().num_waves(),
        session_serial.schedule().max_width()
    );
    let t2 = Instant::now();
    let rep_serial = session_serial.run(Mode::Timing).expect("serial run");
    let t_wave_serial = t2.elapsed().as_secs_f64();
    let t3 = Instant::now();
    let rep_parallel = session_parallel.run(Mode::Timing).expect("parallel run");
    let t_wave_parallel = t3.elapsed().as_secs_f64();
    assert_eq!(
        rep_serial.makespan_cycles, rep_parallel.makespan_cycles,
        "wavefront timing diverged"
    );
    assert_eq!(rep_serial.timing, rep_parallel.timing, "Timing diverged");
    assert_eq!(rep_serial.transactions, rep_parallel.transactions);
    assert_eq!(rep_serial.raw_bytes, rep_parallel.raw_bytes);
    assert_eq!(rep_serial.useful_bytes, rep_parallel.useful_bytes);
    println!(
        "wavefront run      serial {t_wave_serial:7.2}s   {threads} threads {t_wave_parallel:7.2}s   speedup {:.2}x",
        t_wave_serial / t_wave_parallel.max(1e-9)
    );
    let timing = rep_serial.timing.as_ref().expect("timing counters");
    println!(
        "timing bit-identical across thread counts: {} cycles, {} bursts, {} turnarounds",
        rep_serial.makespan_cycles, timing.axi_bursts, timing.turnarounds
    );

    let speedup = t_serial / t_parallel.max(1e-9);
    if threads >= 4 && speedup < 2.0 {
        eprintln!("WARNING: sweep speedup {speedup:.2}x below the 2x target at {threads} threads");
    }
}
